"""``cam-map``: hierarchy mapping (paper §III-D2, Fig. 6) + MappingPlan.

Transforms the flat cam IR into the nested ``scf.parallel`` loop structure
over (banks, mats, arrays, subarrays), allocating devices and partial-result
buffers at each loop level and inserting the merge calls.  If the data
exceeds the system capacity an additional sequential *round* loop over bank
re-fills is introduced (paper: "an additional loop is introduced").

Alongside the IR this pass derives a :class:`MappingPlan` — the closed-form
summary (tile grid, stacking factor, physical subarray count, cycle counts
per optimization mode) that the cost model (`repro.camsim`) and the
vectorized functional executor consume.  IR and plan come from the same
analysis, so they cannot drift apart.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional

from ..arch import AccessMode, ArchSpec
from ..ir import Builder, Module, Operation, Pass, Region, Block, TensorType


@dataclass
class MappingPlan:
    """Everything the cost model needs to know about one mapped search kernel."""

    arch: ArchSpec
    # workload
    m_queries: int
    n_rows: int
    dim: int
    value_bits: int
    metric: str
    k: int
    largest: bool
    # tiling (from compulsory partitioning)
    grid_rows: int
    grid_cols: int
    dims_per_tile: int
    cells_per_value: int
    # mapping
    #: sensing mode — "best" (top-k / WTA periphery) or "range" (every
    #: row's match line is read out: aCAM interval search and the TH
    #: threshold mode).  Informs the camsim sensing-cost selection.
    search_type: str = "best"
    stack: int = 1                   # selective-search batches per subarray
    logical_tiles: int = 0
    physical_subarrays: int = 0
    banks_used: int = 0
    rounds: int = 1                  # sequential bank re-fills if capacity-bound
    search_cycles: int = 0           # total sequential search cycles (per round)
    active_subarrays_per_cycle: float = 0.0
    rows_active_per_search: int = 0
    writes: int = 0                  # subarray write operations
    searches: int = 0                # total subarray-search events (energy)
    merges_horizontal: int = 0
    merges_vertical: int = 0

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["arch"] = {k: v for k, v in asdict(self.arch).items()}
        return d


def derive_plan(arch: ArchSpec, part: Dict[str, Any]) -> MappingPlan:
    """Closed-form mapping derivation from a partition_info record."""
    m = int(part["m"]); n = int(part["n"]); dim = int(part["dim"])
    grid_rows, grid_cols = int(part["grid_rows"]), int(part["grid_cols"])
    logical = grid_rows * grid_cols
    rows_used = min(n, arch.rows)          # data rows per row-batch
    stack = 1
    if arch.selective_search and arch.supports_selective:
        stack = max(1, arch.rows // max(1, rows_used))
        stack = min(stack, logical)        # cannot stack more tiles than exist
    physical = math.ceil(logical / stack)
    per_bank = arch.subarrays_per_bank
    banks_needed = max(1, math.ceil(physical / per_bank))
    if arch.banks and banks_needed > arch.banks:
        rounds = math.ceil(banks_needed / arch.banks)
        banks_used = arch.banks
    else:
        rounds = 1
        banks_used = banks_needed

    # --- cycle accounting (latency model input) -------------------------
    # All queries are searched sequentially; within one query:
    #   * levels with parallel access contribute factor 1,
    #   * sequential levels contribute their occupied count,
    #   * cam-power (max_active_subarrays=1) serializes the subarrays of an
    #     array; latency is set by the most-occupied array,
    #   * selective search serializes the stacked batches.
    arrays_used = max(1, math.ceil(physical / arch.subarrays_per_array))
    subs_in_fullest_array = min(arch.subarrays_per_array,
                                physical if arrays_used == 1
                                else math.ceil(physical / arrays_used))
    sub_factor = 1
    if arch.max_active_subarrays == 1 or arch.access["subarray"] == AccessMode.SEQUENTIAL:
        sub_factor = subs_in_fullest_array
    elif arch.max_active_subarrays > 1:
        sub_factor = math.ceil(subs_in_fullest_array / arch.max_active_subarrays)
    lvl_factor = 1
    mats_used = max(1, math.ceil(arrays_used / arch.arrays_per_mat))
    if arch.access["array"] == AccessMode.SEQUENTIAL:
        lvl_factor *= min(arch.arrays_per_mat, arrays_used)
    if arch.access["mat"] == AccessMode.SEQUENTIAL:
        lvl_factor *= min(arch.mats_per_bank, mats_used)
    if arch.access["bank"] == AccessMode.SEQUENTIAL:
        lvl_factor *= banks_used

    search_cycles = m * stack * sub_factor * lvl_factor
    searches = m * logical                       # energy events: every logical tile
    active = searches / max(1, search_cycles)

    return MappingPlan(
        arch=arch, m_queries=m, n_rows=n, dim=dim,
        value_bits=int(part["value_bits"]), metric=part["metric"],
        k=int(part["k"]), largest=bool(part["largest"]),
        search_type=str(part.get("search_type", "best")),
        grid_rows=grid_rows, grid_cols=grid_cols,
        dims_per_tile=int(part["dims_per_tile"]),
        cells_per_value=int(part["cells_per_value"]),
        stack=stack, logical_tiles=logical, physical_subarrays=physical,
        banks_used=banks_used, rounds=rounds, search_cycles=search_cycles,
        active_subarrays_per_cycle=active,
        rows_active_per_search=rows_used,
        writes=physical * rounds,
        searches=searches,
        merges_horizontal=m * grid_rows * max(0, grid_cols - 1),
        merges_vertical=m * max(0, grid_rows - 1),
    )


class CamMap(Pass):
    name = "cam-map"

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:
        arch: ArchSpec = ctx["arch"]
        plans: List[MappingPlan] = [derive_plan(arch, part)
                                    for part in ctx.get("partition_info", [])]
        ctx["plans"] = plans
        if plans:
            module.attributes["mapping_plans"] = [p.to_dict() for p in plans]

        # Rewrite the flat alloc + tiled/unrolled search section into the
        # Fig.-6 loop-nest form.  We wrap each contiguous cam section into
        # scf.parallel ops with symbolic bounds; per-tile ops stay in the
        # innermost body (one representative body — the loop carries the
        # iteration semantics, as in MLIR, rather than unrolling).
        if not plans:
            return module
        plan = plans[0]
        new = Module(module.name, [a.type for a in module.arguments])
        vmap: Dict[Any, Any] = {}
        for old_a, new_a in zip(module.arguments, new.arguments):
            new_a.name = old_a.name
            vmap[old_a] = new_a
        b = Builder(new.body)

        cam_ops = [op for op in module.ops()
                   if op.dialect in ("cam",) or op.name.startswith("cim.")]
        other = [op for op in module.ops() if op not in cam_ops]

        def loop(level: str, bound: int, mode: str, body_fn) -> Operation:
            blk = Block()
            body_fn(Builder(blk))
            return b.create(
                "scf.parallel" if mode == AccessMode.PARALLEL else "scf.for",
                [], [], {"level": level, "lb": 0, "ub": bound, "step": 1,
                         "mode": mode},
                regions=[Region([blk])])

        a = plan.arch
        sub_mode = (AccessMode.SEQUENTIAL if a.max_active_subarrays == 1
                    else a.access["subarray"])

        def subarray_body(bb: Builder):
            s = bb.create("cam.alloc_subarray", [], [TensorType((), "!cam.subarray_id")])
            attrs = {"metric": plan.metric, "k": plan.k, "largest": plan.largest,
                     "value_bits": plan.value_bits, "stack": plan.stack,
                     "rows_active": plan.rows_active_per_search}
            def batch_body(bbb: Builder):
                bbb.create("cam.write_value", [s.result], [], attrs)
                bbb.create("cam.search", [s.result], [],
                           {"type": plan.search_type,
                            "selective": plan.stack > 1, **attrs})
                rd = bbb.create("cam.read_value", [s.result],
                                [TensorType((plan.m_queries, a.rows), "f32")],
                                {"mode": "raw", **attrs})
                bbb.create("cam.merge_partial_values_horizontal",
                           [rd.result], [rd.result.type], {"dir": "horizontal"})
            if plan.stack > 1:
                bb.create("scf.for", [], [],
                          {"level": "selective_batch", "lb": 0, "ub": plan.stack,
                           "step": 1, "mode": AccessMode.SEQUENTIAL},
                          regions=[Region([self._subblock(batch_body)])])
            else:
                batch_body(bb)

        def array_body(bb: Builder):
            bb.create("cam.alloc_array", [], [TensorType((), "!cam.array_id")])
            inner = self._subblock(subarray_body)
            bb.create("scf.parallel" if sub_mode == AccessMode.PARALLEL else "scf.for",
                      [], [], {"level": "subarray", "lb": 0,
                               "ub": min(a.subarrays_per_array, plan.physical_subarrays),
                               "step": 1, "mode": sub_mode},
                      regions=[Region([inner])])
            bb.create("cam.reduce_topk", [], [], {"k": plan.k, "largest": plan.largest})
            bb.create("cam.merge_partial_values_indices_vertical", [], [],
                      {"dir": "vertical"})

        def mat_body(bb: Builder):
            bb.create("cam.alloc_mat", [], [TensorType((), "!cam.mat_id")])
            bb.create("scf.parallel" if a.access["array"] == AccessMode.PARALLEL else "scf.for",
                      [], [], {"level": "array", "lb": 0, "ub": a.arrays_per_mat,
                               "step": 1, "mode": a.access["array"]},
                      regions=[Region([self._subblock(array_body)])])

        def bank_body(bb: Builder):
            bb.create("cam.alloc_bank", [], [TensorType((), "!cam.bank_id")],
                      {"rows": a.rows, "cols": a.cols})
            bb.create("scf.parallel" if a.access["mat"] == AccessMode.PARALLEL else "scf.for",
                      [], [], {"level": "mat", "lb": 0, "ub": a.mats_per_bank,
                               "step": 1, "mode": a.access["mat"]},
                      regions=[Region([self._subblock(mat_body)])])

        def round_body(bb: Builder):
            inner = self._subblock(bank_body)
            bb.create("scf.parallel" if a.access["bank"] == AccessMode.PARALLEL else "scf.for",
                      [], [], {"level": "bank", "lb": 0, "ub": plan.banks_used,
                               "step": 1, "mode": a.access["bank"]},
                      regions=[Region([inner])])

        if plan.rounds > 1:
            loop("round", plan.rounds, AccessMode.SEQUENTIAL, round_body)
        else:
            round_body(b)

        # host-side ops and return are carried over
        for op in other:
            if op.name == "func.return":
                continue
            new.body.append(op.clone(vmap))
        rets = []
        for v in module.return_values():
            rets.append(vmap.get(v, v))
        # results of the mapped program come from device buffers; represent
        # with a cam.gather_results op typed like the original returns
        gr = b.create("cam.gather_results", [],
                      [v.type for v in rets], {"source": "device_buffers"})
        b.ret(list(gr.results))
        new.attributes.update(module.attributes)
        return new

    @staticmethod
    def _subblock(fn) -> Block:
        blk = Block()
        fn(Builder(blk))
        return blk
