"""Compulsory partitioning (paper §III-D1, Fig. 5d).

Kernels usually exceed the capacity of a single subarray (the smallest block
of the CAM system), so fused ``cim.similarity`` ops are tiled to subarray
granularity.  The transformation "can be likened to tiling in compiler
terminology, with hardware-specific considerations":

* pattern rows are split into ``grid_rows`` row-batches of at most R rows,
* pattern columns (after cell-encoding: ``value_bits / bits_per_cell`` cells
  per element) are split into ``grid_cols`` column tiles of at most C cells,
* partial distances across column tiles are accumulated with
  ``cim.merge_partial {dir = horizontal}``,
* per-row-batch top-k candidate lists are tournament-merged with
  ``cim.merge_partial {dir = vertical}`` (``cim.merge_partial`` "considers
  both the type of operation ... and the direction", §III-D1).

For small grids (<= ``unroll_limit`` tiles) the pass emits the fully
explicit per-tile IR of Fig. 5d; for large grids it emits one
``cim.tiled_similarity`` op carrying the grid as attributes — identical
semantics, loop-structured lowering (the cam-map pass generates the loops
either way, like MLIR's scf tiling would).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List

from ..arch import ArchSpec
from ..cim_dialect import make_yield
from ..ir import Module, Operation, Pass, TensorType, Value


def tile_grid(arch: ArchSpec, n_rows: int, dim: int, value_bits: int):
    """(grid_rows, grid_cols, cols_per_value, dims_per_tile) for a pattern set."""
    cells_per_value = max(1, math.ceil(value_bits / arch.bits_per_cell))
    dims_per_tile = max(1, arch.cols // cells_per_value)
    grid_cols = math.ceil(dim / dims_per_tile)
    grid_rows = math.ceil(n_rows / arch.rows)
    return grid_rows, grid_cols, cells_per_value, dims_per_tile


class CompulsoryPartition(Pass):
    name = "cim-partition"

    def __init__(self, unroll_limit: int = 64):
        self.unroll_limit = unroll_limit

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:
        arch: ArchSpec = ctx["arch"]
        for exe in module.ops():
            if exe.name != "cim.execute":
                continue
            body = exe.body_ops()
            sims = [op for op in body if op.name == "cim.similarity"]
            ranges = [op for op in body if op.name == "cim.range_search"]
            if not sims and not ranges:
                continue
            blk = exe.region().block()
            for sim in sims:
                self._partition_one(blk, sim, arch, ctx)
            for rs in ranges:
                self._partition_range(blk, rs, arch, ctx)
        return module

    # ------------------------------------------------------------------
    def _partition_one(self, blk, sim: Operation, arch: ArchSpec,
                       ctx: Dict[str, Any]) -> None:
        queries, patterns = sim.operands[0], sim.operands[1]
        ternary = len(sim.operands) == 3     # TCAM wildcard care mask
        n_rows, dim = patterns.type.shape[-2], patterns.type.shape[-1]
        m = 1
        for d in queries.type.shape[:-1]:
            m *= d
        value_bits = int(sim.attributes.get("value_bits", 8))
        grid_rows, grid_cols, cpv, dpt = tile_grid(arch, n_rows, dim, value_bits)
        k = int(sim.attributes["k"])
        largest = bool(sim.attributes["largest"])
        metric = sim.attributes["metric"]
        common = {"metric": metric, "k": k, "largest": largest,
                  "value_bits": value_bits, "grid_rows": grid_rows,
                  "grid_cols": grid_cols, "tile_rows": arch.rows,
                  "tile_cols": arch.cols, "dims_per_tile": dpt,
                  "cells_per_value": cpv, "m": m, "n": n_rows, "dim": dim}
        ctx.setdefault("partition_info", []).append(dict(common))

        if ternary:
            # the care mask rides every tile; emit the loop-structured op
            # (the engine packs it per column tile, the interpreter masks
            # its mismatch counts — Fig.-5d unrolling would triple the
            # per-tile operand wiring for no semantic gain)
            common["ternary"] = True
            new_ops = [Operation("cim.tiled_similarity", list(sim.operands),
                                 [r.type for r in sim.results], dict(common))]
        elif grid_rows * grid_cols <= self.unroll_limit:
            new_ops = self._emit_unrolled(sim, queries, patterns, common)
        else:
            new_ops = [Operation("cim.tiled_similarity", [queries, patterns],
                                 [r.type for r in sim.results], dict(common))]
        # splice: replace sim with new_ops, rewiring result uses via yield
        idx = blk.operations.index(sim)
        blk.operations[idx:idx + 1] = new_ops
        for op in new_ops:
            op.parent = blk
        final = new_ops[-1]
        mapping = dict(zip(sim.results, final.results))
        for op in blk.operations:
            op.operands = [mapping.get(v, v) for v in op.operands]

    # ------------------------------------------------------------------
    def _partition_range(self, blk, rs: Operation, arch: ArchSpec,
                         ctx: Dict[str, Any]) -> None:
        """Tile a ``cim.range_search`` to subarray granularity.

        Range search has no cross-tile candidate tournament: column
        tiles still accumulate partial distances / violation counts
        (``merge_partial horizontal``), but row tiles *concatenate*
        their boolean match slices — every stored row reports its own
        match line, so the loop-structured ``cim.tiled_range_search``
        form is emitted for every grid size (unrolling would only
        replicate the concatenation wiring).
        """
        queries = rs.operands[0]
        stored = rs.operands[1]          # patterns, or the lo bound
        n_rows, dim = stored.type.shape[-2], stored.type.shape[-1]
        m = 1
        for d in queries.type.shape[:-1]:
            m *= d
        mode = rs.attributes.get("mode", "threshold")
        value_bits = int(rs.attributes.get("value_bits", 8))
        grid_rows, grid_cols, cpv, dpt = tile_grid(arch, n_rows, dim,
                                                   value_bits)
        common = dict(rs.attributes)
        common.update({"value_bits": value_bits, "grid_rows": grid_rows,
                       "grid_cols": grid_cols, "tile_rows": arch.rows,
                       "tile_cols": arch.cols, "dims_per_tile": dpt,
                       "cells_per_value": cpv, "m": m, "n": n_rows,
                       "dim": dim})
        info = dict(common)
        # MappingPlan/cost-model fields the similarity records carry;
        # a range search senses every row's match line (no top-k)
        info.setdefault("metric", "interval" if mode == "interval"
                        else rs.attributes["metric"])
        info.update({"k": 0, "largest": False, "search_type": "range"})
        ctx.setdefault("partition_info", []).append(info)
        tiled = Operation("cim.tiled_range_search", list(rs.operands),
                          [r.type for r in rs.results], common)
        idx = blk.operations.index(rs)
        blk.operations[idx:idx + 1] = [tiled]
        tiled.parent = blk
        mapping = dict(zip(rs.results, tiled.results))
        for op in blk.operations:
            op.operands = [mapping.get(v, v) for v in op.operands]

    # ------------------------------------------------------------------
    def _emit_unrolled(self, sim: Operation, queries: Value, patterns: Value,
                       a: Dict[str, Any]) -> List[Operation]:
        """Explicit Fig.-5d style tile ops for small grids."""
        ops: List[Operation] = []
        m, k = a["m"], a["k"]
        dist_t = TensorType((m, a["tile_rows"]), "f32")
        vt = sim.results[0].type
        it = sim.results[1].type
        # dot/cos similarity physically runs as Hamming distance on the CAM
        # (bipolar encoding); the on-device top-k therefore has flipped
        # polarity, and reshape_result converts values back to the logical
        # metric domain (dot = D - 2*hamming).
        phys_largest = (not a["largest"]) if a["metric"] in ("dot", "cos") \
            else a["largest"]
        merged_rows: List[Operation] = []
        for r in range(a["grid_rows"]):
            acc: Value = None
            for c in range(a["grid_cols"]):
                st = Operation("cim.search_tile", [queries, patterns], [dist_t],
                               {"row_tile": r, "col_tile": c,
                                "metric": a["metric"],
                                "phys_largest": phys_largest,
                                "dims_per_tile": a["dims_per_tile"],
                                "tile_rows": a["tile_rows"]})
                ops.append(st)
                if acc is None:
                    acc = st.result
                else:
                    mp = Operation("cim.merge_partial", [acc, st.result],
                                   [dist_t], {"dir": "horizontal"})
                    ops.append(mp)
                    acc = mp.result
            tk = Operation("cim.topk_tile", [acc], [TensorType((m, k), vt.dtype),
                                                    TensorType((m, k), "i32")],
                           {"k": k, "largest": phys_largest, "row_tile": r,
                            "tile_rows": a["tile_rows"]})
            ops.append(tk)
            merged_rows.append(tk)
        acc_v, acc_i = merged_rows[0].results
        for r, tk in enumerate(merged_rows[1:], start=1):
            mp = Operation("cim.merge_partial",
                           [acc_v, acc_i, tk.results[0], tk.results[1]],
                           [TensorType((m, k), vt.dtype), TensorType((m, k), "i32")],
                           {"dir": "vertical", "row_offset": r * a["tile_rows"],
                            "largest": phys_largest})
            ops.append(mp)
            acc_v, acc_i = mp.results
        fin = Operation("cim.reshape_result", [acc_v, acc_i], [vt, it],
                        {"m": m, "k": k, "metric": a["metric"],
                         "dim": a["dim"]})
        ops.append(fin)
        return ops
