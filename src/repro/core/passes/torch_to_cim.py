"""``torch-to-cim`` conversion (paper §III-D, Fig. 5a).

The fundamental assumption of the conversion (quoting the paper) is that
*each supported operation can be executed on a separate (non-)CIM device*:
every torch op that the cim abstraction supports is wrapped into its own
``cim.acquire`` / ``cim.execute`` / ``cim.release`` triple.  Unsupported ops
(none in our vocabulary, but kept general) stay in the host dialect.
"""

from __future__ import annotations

from typing import Any, Dict

from ..cim_dialect import CIM_COMPUTE_OPS, make_acquire, make_execute, make_release, make_yield
from ..ir import Builder, Module, Operation, Pass


class TorchToCim(Pass):
    name = "torch-to-cim"

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:
        new = Module(module.name, [a.type for a in module.arguments])
        vmap = {}
        for old_a, new_a in zip(module.arguments, new.arguments):
            new_a.name = old_a.name
            vmap[old_a] = new_a
        b = Builder(new.body)
        for op in module.body.operations:
            if op.name == "func.return":
                b.ret([vmap[v] for v in op.operands])
                continue
            if op.name not in CIM_COMPUTE_OPS:
                # host fallback: keep the op as-is (standard MLIR pipeline)
                cloned = op.clone(vmap)
                new.body.append(cloned)
                continue
            handle = make_acquire(b).result
            exe = make_execute(b, handle, [vmap[v] for v in op.operands],
                               [r.type for r in op.results])
            inner = Operation(CIM_COMPUTE_OPS[op.name],
                              [vmap[v] for v in op.operands],
                              [r.type for r in op.results],
                              dict(op.attributes))
            exe.region().block().append(inner)
            make_yield(exe.region().block(), inner.results)
            for old_r, new_r in zip(op.results, exe.results):
                vmap[old_r] = new_r
            make_release(b, handle)
        return new
