"""``cim-to-cam`` conversion (paper §III-D2).

Sequences of ``cim.acquire / cim.execute / cim.release`` on one device
handle are substituted with the allocation of a *simple system* (one bank,
one mat, one array, one subarray), and ``cim.execute`` is lowered into the
three CAM calls: ``cam.write_value``, ``cam.search`` and ``cam.read_value``.

The pass takes the target CAM device type (TCAM / MCAM / ACAM) as a
parameter, which determines the search type and metric used:

* ``dot``/``cos`` similarity on binary data -> Hamming best-match (for
  bipolar hypervectors, ``argmax q.p == argmin hamming(q, p)``),
* ``eucl`` -> analog range/best search on ACAM/MCAM, Hamming approximation
  with thermometer-coded multi-bit cells on TCAM,
* ``k == 1`` uses the winner-take-all ``best`` sensing mode, ``k > 1`` keeps
  counting/ADC sensing (``best`` with k), threshold attrs use ``range``.

Tensor bufferization is notional here: buffers are attributes on the ops
(host/device transfer is accounted by the cost model, and the functional
executor materializes them as JAX arrays).
"""

from __future__ import annotations

from typing import Any, Dict, List

from ..arch import ArchSpec, CamType, Metric, SearchType
from ..ir import Builder, Module, Operation, Pass, Region, Block, TensorType, Value

CAM_ID = lambda kind: TensorType((), f"!cam.{kind}_id")  # noqa: E731


def device_search_config(cam_type: str, metric: str, value_bits: int) -> Dict[str, Any]:
    """Map (device type, cim metric) -> physical search type + metric."""
    if metric in ("dot", "cos"):
        # binary/bipolar data: dot-similarity == Hamming distance search
        return {"metric": Metric.HAMMING, "encoding": "bipolar"}
    if metric == "eucl":
        if cam_type in (CamType.ACAM, CamType.MCAM):
            return {"metric": Metric.EUCLIDEAN, "encoding": "analog"}
        return {"metric": Metric.EUCLIDEAN, "encoding": "thermometer"}
    if metric == "hamming":
        return {"metric": Metric.HAMMING, "encoding": "binary"}
    raise ValueError(f"unsupported metric {metric}")


class CimToCam(Pass):
    name = "cim-to-cam"

    def __init__(self, cam_type: str = CamType.TCAM):
        self.cam_type = cam_type

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:
        arch: ArchSpec = ctx["arch"]
        new = Module(module.name, [a.type for a in module.arguments])
        vmap: Dict[Value, Value] = {}
        for old_a, new_a in zip(module.arguments, new.arguments):
            new_a.name = old_a.name
            vmap[old_a] = new_a
        b = Builder(new.body)
        i = 0
        ops = module.ops()
        while i < len(ops):
            op = ops[i]
            if op.name == "cim.acquire" and i + 2 < len(ops) \
                    and ops[i + 1].name == "cim.execute" \
                    and ops[i + 2].name == "cim.release":
                self._lower_execute(b, ops[i + 1], vmap, arch, ctx)
                i += 3
                continue
            if op.name == "func.return":
                b.ret([vmap.get(v, v) for v in op.operands])
                i += 1
                continue
            new.body.append(op.clone(vmap))
            i += 1
        return new

    # ------------------------------------------------------------------
    def _lower_execute(self, b: Builder, exe: Operation,
                       vmap: Dict[Value, Value], arch: ArchSpec,
                       ctx: Dict[str, Any]) -> None:
        # allocate the simple system: one bank -> mat -> array -> subarray
        bank = b.create("cam.alloc_bank", [], [CAM_ID("bank")],
                        {"rows": arch.rows, "cols": arch.cols})
        mat = b.create("cam.alloc_mat", [bank.result], [CAM_ID("mat")])
        arr = b.create("cam.alloc_array", [mat.result], [CAM_ID("array")])
        sub = b.create("cam.alloc_subarray", [arr.result], [CAM_ID("subarray")])
        handles = {"bank": bank.result, "mat": mat.result,
                   "array": arr.result, "subarray": sub.result}

        inner_map: Dict[Value, Value] = dict(vmap)
        for op in exe.body_ops():
            if op.name == "cim.yield":
                for outer_r, y in zip(exe.results, op.operands):
                    vmap[outer_r] = inner_map.get(y, y)
                continue
            self._lower_op(b, op, inner_map, handles, arch, ctx)

    def _lower_op(self, b: Builder, op: Operation, inner_map: Dict[Value, Value],
                  handles: Dict[str, Value], arch: ArchSpec,
                  ctx: Dict[str, Any]) -> None:
        def opnd(i: int) -> Value:
            return inner_map.get(op.operands[i], op.operands[i])

        sub = handles["subarray"]
        if op.name in ("cim.range_search", "cim.tiled_range_search"):
            mode = op.attributes.get("mode", "threshold")
            if mode == "interval":
                # interval cells only exist on analog CAMs: each cell
                # stores [lo, hi] as two conductances (Li et al.); a
                # digital TCAM/BCAM has no encoding for them
                if self.cam_type != CamType.ACAM:
                    raise ValueError(
                        f"interval range search requires cam_type="
                        f"'{CamType.ACAM}' (analog interval cells), got "
                        f"{self.cam_type!r}")
                cfg = {"metric": "interval", "encoding": "analog"}
            else:
                value_bits = int(op.attributes.get("value_bits", 8))
                cfg = device_search_config(self.cam_type,
                                           op.attributes["metric"],
                                           value_bits)
            attrs = dict(op.attributes)
            attrs.update(cfg)
            attrs["cam_type"] = self.cam_type
            # interval rows program two bounds per cell; threshold rows
            # store patterns like a best-match search
            b.create("cam.write_value", [sub, *map(opnd, range(1, len(op.operands)))],
                     [], attrs)
            b.create("cam.search", [sub, opnd(0)], [],
                     {"type": SearchType.RANGE, **attrs})
            r = b.create("cam.read_value", [sub],
                         [res.type for res in op.results],
                         {"mode": "match_lines", **attrs})
            for old_r, new_r in zip(op.results, r.results):
                inner_map[old_r] = new_r
            ctx.setdefault("cam_search_configs", []).append(
                {"search_type": SearchType.RANGE, **cfg,
                 "cam_type": self.cam_type})
            return
        if op.name in ("cim.search_tile", "cim.tiled_similarity"):
            value_bits = int(op.attributes.get("value_bits", 8))
            cfg = device_search_config(self.cam_type,
                                       op.attributes["metric"], value_bits)
            search_type = SearchType.BEST if op.attributes.get("k", 0) else SearchType.RANGE
            if op.attributes.get("k", 0) == 1:
                op.attributes["sensing"] = "wta"     # winner-take-all circuit
            attrs = dict(op.attributes)
            attrs.update(cfg)
            attrs["cam_type"] = self.cam_type
            w = b.create("cam.write_value", [sub, opnd(1)], [], attrs)
            s = b.create("cam.search", [sub, opnd(0)], [],
                         {"type": search_type, **attrs})
            mode = "raw" if op.name == "cim.search_tile" else "merged"
            r = b.create("cam.read_value", [sub],
                         [res.type for res in op.results],
                         {"mode": mode, **attrs})
            for old_r, new_r in zip(op.results, r.results):
                inner_map[old_r] = new_r
            ctx.setdefault("cam_search_configs", []).append(
                {"search_type": search_type, **cfg, "cam_type": self.cam_type})
            return
        if op.name == "cim.merge_partial":
            direction = op.attributes["dir"]
            kind = "values" if len(op.operands) == 2 else "values_indices"
            cam_name = f"cam.merge_partial_{kind}_{direction}"
            m = b.create(cam_name, [inner_map.get(v, v) for v in op.operands],
                         [r.type for r in op.results], dict(op.attributes))
            for old_r, new_r in zip(op.results, m.results):
                inner_map[old_r] = new_r
            return
        if op.name in ("cim.topk_tile", "cim.reshape_result"):
            nm = {"cim.topk_tile": "cam.reduce_topk",
                  "cim.reshape_result": "cam.reshape_result"}[op.name]
            m = b.create(nm, [inner_map.get(v, v) for v in op.operands],
                         [r.type for r in op.results], dict(op.attributes))
            for old_r, new_r in zip(op.results, m.results):
                inner_map[old_r] = new_r
            return
        # non-similarity cim compute (host-assisted): keep as cim.* op — the
        # executor runs these on the host (standard MLIR pipeline path).
        cloned = op.clone(inner_map)
        b.block.append(cloned)
        for old_r, new_r in zip(op.results, cloned.results):
            inner_map[old_r] = new_r
