"""Lightweight MLIR-like IR infrastructure for the C4CAM reproduction.

This intentionally mirrors the small subset of MLIR that C4CAM relies on:

* SSA ``Value``s carrying tensor types,
* ``Operation``s grouped into dialects via a ``"dialect.opname"`` naming
  convention, with attributes and (optionally) nested regions,
* ``Block``/``Region``/``Module`` containers,
* a ``PassManager`` running rewrite passes, each of which records the IR
  snapshot so the progressive-lowering pipeline can be inspected (this is
  what the paper's Fig. 4/5/6 show at each abstraction level).

MLIR itself is *not* a dependency; the textual form produced by
:meth:`Module.dump` is MLIR-flavoured for readability only.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "TensorType",
    "Value",
    "Operation",
    "Block",
    "Region",
    "Module",
    "Builder",
    "Pass",
    "PassManager",
    "IRError",
    "verify",
]


class IRError(RuntimeError):
    """Raised on malformed IR or failed verification."""


# ---------------------------------------------------------------------------
# Types and values
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TensorType:
    """A ranked tensor type, ``tensor<4x8xf32>`` style.

    ``shape`` entries of ``-1`` denote dynamic dims (unused in the paper's
    flow but kept for generality).
    """

    shape: Tuple[int, ...]
    dtype: str = "f32"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        dims = "x".join(str(d) if d >= 0 else "?" for d in self.shape)
        return f"tensor<{dims}x{self.dtype}>" if self.shape else f"tensor<{self.dtype}>"

    @property
    def rank(self) -> int:
        return len(self.shape)

    def num_elements(self) -> int:
        n = 1
        for d in self.shape:
            n *= max(d, 0)
        return n


_value_ids = itertools.count()


class Value:
    """An SSA value produced by an operation (or a block argument)."""

    __slots__ = ("type", "producer", "index", "name", "id")

    def __init__(self, type: TensorType, producer: Optional["Operation"] = None,
                 index: int = 0, name: Optional[str] = None):
        self.type = type
        self.producer = producer
        self.index = index
        self.id = next(_value_ids)
        self.name = name or f"%{self.id}"

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}: {self.type}"


# ---------------------------------------------------------------------------
# Operations / blocks / regions
# ---------------------------------------------------------------------------


class Operation:
    """A generic operation: ``results = dialect.name(operands) {attrs}``."""

    def __init__(
        self,
        name: str,
        operands: Sequence[Value] = (),
        result_types: Sequence[TensorType] = (),
        attributes: Optional[Dict[str, Any]] = None,
        regions: Optional[List["Region"]] = None,
    ):
        if "." not in name:
            raise IRError(f"operation name must be 'dialect.op', got {name!r}")
        self.name = name
        self.operands: List[Value] = list(operands)
        self.attributes: Dict[str, Any] = dict(attributes or {})
        self.regions: List[Region] = regions or []
        self.results: List[Value] = [
            Value(t, producer=self, index=i) for i, t in enumerate(result_types)
        ]
        self.parent: Optional[Block] = None

    # -- convenience -------------------------------------------------------
    @property
    def dialect(self) -> str:
        return self.name.split(".", 1)[0]

    @property
    def opname(self) -> str:
        return self.name.split(".", 1)[1]

    @property
    def result(self) -> Value:
        if len(self.results) != 1:
            raise IRError(f"{self.name} has {len(self.results)} results, expected 1")
        return self.results[0]

    def region(self, i: int = 0) -> "Region":
        return self.regions[i]

    def body_ops(self) -> List["Operation"]:
        """Ops of the first block of the first region (execute-style ops)."""
        if not self.regions or not self.regions[0].blocks:
            return []
        return list(self.regions[0].blocks[0].operations)

    def erase(self) -> None:
        if self.parent is not None:
            self.parent.operations.remove(self)
            self.parent = None

    def replace_all_uses_with(self, mapping: Dict[Value, Value], root: "Operation") -> None:
        """Within ``root`` (recursively), remap operands per ``mapping``."""
        for op in root.walk():
            op.operands = [mapping.get(v, v) for v in op.operands]

    def walk(self) -> Iterator["Operation"]:
        yield self
        for region in self.regions:
            for block in region.blocks:
                for op in list(block.operations):
                    yield from op.walk()

    def clone(self, value_map: Optional[Dict[Value, Value]] = None) -> "Operation":
        value_map = value_map if value_map is not None else {}
        new = Operation(
            self.name,
            [value_map.get(v, v) for v in self.operands],
            [r.type for r in self.results],
            dict(self.attributes),
        )
        for old_r, new_r in zip(self.results, new.results):
            value_map[old_r] = new_r
        for region in self.regions:
            new.regions.append(region.clone(value_map))
        return new

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return _print_op(self, indent=0)


class Block:
    def __init__(self, arg_types: Sequence[TensorType] = ()):  # noqa: D401
        self.arguments: List[Value] = [Value(t) for t in arg_types]
        self.operations: List[Operation] = []

    def append(self, op: Operation) -> Operation:
        op.parent = self
        self.operations.append(op)
        return op

    def insert_before(self, anchor: Operation, op: Operation) -> Operation:
        idx = self.operations.index(anchor)
        op.parent = self
        self.operations.insert(idx, op)
        return op

    def clone(self, value_map: Dict[Value, Value]) -> "Block":
        new = Block()
        new.arguments = []
        for arg in self.arguments:
            na = Value(arg.type, name=arg.name)
            value_map[arg] = na
            new.arguments.append(na)
        for op in self.operations:
            new.append(op.clone(value_map))
        return new


class Region:
    def __init__(self, blocks: Optional[List[Block]] = None):
        self.blocks: List[Block] = blocks or []

    def block(self, i: int = 0) -> Block:
        return self.blocks[i]

    def clone(self, value_map: Dict[Value, Value]) -> "Region":
        return Region([b.clone(value_map) for b in self.blocks])


class Module:
    """Top-level container: a single function-like body (the traced kernel)."""

    def __init__(self, name: str, arg_types: Sequence[TensorType],
                 arg_names: Optional[Sequence[str]] = None):
        self.name = name
        self.body = Block(arg_types)
        if arg_names:
            for v, n in zip(self.body.arguments, arg_names):
                v.name = f"%{n}"
        self.attributes: Dict[str, Any] = {}

    @property
    def arguments(self) -> List[Value]:
        return self.body.arguments

    def ops(self) -> List[Operation]:
        return list(self.body.operations)

    def walk(self) -> Iterator[Operation]:
        for op in list(self.body.operations):
            yield from op.walk()

    def return_values(self) -> List[Value]:
        for op in reversed(self.body.operations):
            if op.name == "func.return":
                return list(op.operands)
        raise IRError("module has no func.return")

    def dump(self) -> str:
        lines = [f"func.func @{self.name}("
                 + ", ".join(f"{a.name}: {a.type}" for a in self.arguments) + ") {"]
        for op in self.body.operations:
            lines.append(_print_op(op, indent=1))
        lines.append("}")
        return "\n".join(lines)

    def clone(self) -> "Module":
        new = Module(self.name, [a.type for a in self.arguments])
        vmap: Dict[Value, Value] = {}
        for old_a, new_a in zip(self.arguments, new.arguments):
            new_a.name = old_a.name
            vmap[old_a] = new_a
        for op in self.body.operations:
            new.body.append(op.clone(vmap))
        return new


# ---------------------------------------------------------------------------
# Printing
# ---------------------------------------------------------------------------


def _fmt_attr(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, str):
        return f'"{v}"'
    return str(v)


def _print_op(op: Operation, indent: int) -> str:
    pad = "  " * indent
    res = ", ".join(r.name for r in op.results)
    eq = f"{res} = " if res else ""
    args = ", ".join(o.name for o in op.operands)
    attrs = ""
    if op.attributes:
        attrs = " {" + ", ".join(f"{k} = {_fmt_attr(v)}" for k, v in sorted(op.attributes.items())) + "}"
    types = ""
    if op.operands or op.results:
        in_t = ", ".join(str(o.type) for o in op.operands)
        out_t = ", ".join(str(r.type) for r in op.results)
        types = f" : ({in_t}) -> ({out_t})"
    head = f"{pad}{eq}{op.name}({args}){attrs}{types}"
    if not op.regions:
        return head
    lines = [head + " {"]
    for region in op.regions:
        for bi, block in enumerate(region.blocks):
            if block.arguments:
                lines.append("  " * (indent + 1) + "^bb(" +
                             ", ".join(f"{a.name}: {a.type}" for a in block.arguments) + "):")
            for inner in block.operations:
                lines.append(_print_op(inner, indent + 1))
    lines.append(pad + "}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


class Builder:
    """Appends operations to a block (module body or region block)."""

    def __init__(self, block: Block):
        self.block = block

    def create(self, name: str, operands: Sequence[Value] = (),
               result_types: Sequence[TensorType] = (),
               attributes: Optional[Dict[str, Any]] = None,
               regions: Optional[List[Region]] = None) -> Operation:
        op = Operation(name, operands, result_types, attributes, regions)
        self.block.append(op)
        return op

    def ret(self, values: Sequence[Value]) -> Operation:
        return self.create("func.return", values)


# ---------------------------------------------------------------------------
# Verification
# ---------------------------------------------------------------------------


def verify(module: Module) -> None:
    """Checks SSA dominance within straight-line blocks and operand validity."""

    def check_block(block: Block, visible: set) -> None:
        visible = set(visible)
        visible.update(id(a) for a in block.arguments)
        for op in block.operations:
            for operand in op.operands:
                if id(operand) not in visible:
                    raise IRError(
                        f"operand {operand.name} of {op.name} does not dominate its use")
            for region in op.regions:
                for inner in region.blocks:
                    check_block(inner, visible)
            visible.update(id(r) for r in op.results)

    check_block(module.body, set())
    if not any(op.name == "func.return" for op in module.body.operations):
        raise IRError("module missing func.return")


# ---------------------------------------------------------------------------
# Pass infrastructure
# ---------------------------------------------------------------------------


class Pass:
    """Base class. Subclasses set ``name`` and implement :meth:`run`."""

    name: str = "<abstract>"

    def run(self, module: Module, ctx: Dict[str, Any]) -> Module:  # pragma: no cover
        raise NotImplementedError


@dataclass
class PassManager:
    passes: List[Pass] = field(default_factory=list)
    verify_each: bool = True
    keep_snapshots: bool = True
    snapshots: List[Tuple[str, str]] = field(default_factory=list)

    def add(self, p: Pass) -> "PassManager":
        self.passes.append(p)
        return self

    def run(self, module: Module, ctx: Optional[Dict[str, Any]] = None) -> Module:
        ctx = ctx if ctx is not None else {}
        self.snapshots = [("input", module.dump())] if self.keep_snapshots else []
        for p in self.passes:
            module = p.run(module, ctx)
            if self.verify_each:
                verify(module)
            if self.keep_snapshots:
                self.snapshots.append((p.name, module.dump()))
        return module
