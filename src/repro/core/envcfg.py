"""Strict, centralised ``REPRO_*`` environment-variable parsing.

Every knob the engine / serving / benchmark layers read from the
environment goes through one of these helpers.  The historical parsers
were permissive in the dangerous direction: ``REPRO_ENGINE_PACK=offf``
(a typo) silently meant *on*, and ``REPRO_ENGINE_MAX_CHUNK=1k`` raised
a bare ``ValueError`` from ``int()`` deep inside plan construction.
Here garbage raises a :class:`ValueError` naming the variable, the
offending value, and what would have been accepted — at the *first*
read, not after a plan half-built itself around a default.

Unset variables always mean the documented default; the helpers never
read anything but ``os.environ``.
"""

import os
from typing import Optional, Sequence, Tuple

__all__ = [
    "env_flag", "env_int", "env_float", "env_choice", "env_gate",
    "env_path",
]

#: accepted spellings for boolean-ish flags (case-insensitive)
_TRUE: Tuple[str, ...] = ("1", "true", "on", "yes")
_FALSE: Tuple[str, ...] = ("0", "false", "off", "no")


def _bad(name: str, raw: str, expected: str) -> ValueError:
    return ValueError(
        f"invalid {name}={raw!r}: expected {expected} "
        f"(unset the variable for the default)")


def env_flag(name: str, default: bool, *,
             auto_means_default: bool = True) -> bool:
    """Boolean flag: ``1/true/on/yes`` vs ``0/false/off/no``.

    ``auto`` maps to the default when ``auto_means_default`` — the
    engine kill switches document ``auto`` as "engine decides", which
    is exactly the unset behaviour.
    """
    raw = os.environ.get(name)
    if raw is None:
        return default
    v = raw.strip().lower()
    if auto_means_default and v == "auto":
        return default
    if v in _TRUE:
        return True
    if v in _FALSE:
        return False
    expected = "one of " + "/".join(_TRUE + _FALSE)
    if auto_means_default:
        expected += " (or 'auto')"
    raise _bad(name, raw, expected)


def env_int(name: str, default: int, *,
            min_value: Optional[int] = None,
            max_value: Optional[int] = None) -> int:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        v = int(raw.strip())
    except ValueError:
        raise _bad(name, raw, "an integer") from None
    if min_value is not None and v < min_value:
        raise _bad(name, raw, f"an integer >= {min_value}")
    if max_value is not None and v > max_value:
        raise _bad(name, raw, f"an integer <= {max_value}")
    return v


def env_float(name: str, default: float, *,
              min_value: Optional[float] = None) -> float:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        v = float(raw.strip())
    except ValueError:
        raise _bad(name, raw, "a number") from None
    if v != v:      # NaN poisons every comparison downstream
        raise _bad(name, raw, "a number (not NaN)")
    if min_value is not None and v < min_value:
        raise _bad(name, raw, f"a number >= {min_value}")
    return v


def env_choice(name: str, default: str, choices: Sequence[str]) -> str:
    raw = os.environ.get(name)
    if raw is None:
        return default
    v = raw.strip().lower()
    if v not in choices:
        raise _bad(name, raw, "one of " + "/".join(choices))
    return v


def env_path(name: str, default: Optional[str] = None) -> Optional[str]:
    """Filesystem-path knob.  An empty or whitespace-only value is a
    shell quoting accident (``REPRO_TRACE= python ...``), not a request
    to write to ``""`` — it raises rather than silently disabling or
    producing an unopenable path."""
    raw = os.environ.get(name)
    if raw is None:
        return default
    if not raw.strip():
        raise _bad(name, raw, "a non-empty filesystem path")
    return raw


def env_gate(name: str, auto: float) -> float:
    """Benchmark acceptance-gate knob: ``auto`` -> the suite's default
    threshold, ``off``/``0`` -> disabled (0.0), otherwise a float."""
    raw = os.environ.get(name)
    if raw is None:
        return auto
    v = raw.strip().lower()
    if v == "auto":
        return auto
    if v in _FALSE:
        return 0.0
    try:
        return float(v)
    except ValueError:
        raise _bad(name, raw, "'auto', 'off', or a number") from None
