"""C4CAM compile driver (paper Fig. 3).

``compile_module`` runs the progressive-lowering pipeline::

    torch IR --torch-to-cim--> cim IR --cim-fuse-ops + similarity-match-->
    fused cim --cim-partition--> partitioned cim --cim-to-cam--> cam IR
    --cam-map--> mapped cam IR (+ MappingPlans)

and returns a :class:`CompiledCamProgram` bundling

* every IR snapshot (inspectable, MLIR-flavoured text),
* a jitted functional executable (host JAX simulation of the CAM),
* the :class:`~repro.core.passes.cam_map.MappingPlan`s,
* a cost report from the Eva-CAM-analog model (`repro.camsim`).

The entry points mirror the paper's CLI: an application (traced
TorchScript-like callable), an architecture description (:class:`ArchSpec`,
§III-B), and an optimization target (latency / power / density /
power+density).

Execution engine & plan cache
-----------------------------
``compile_module`` additionally lowers pure similarity programs into a
:class:`~repro.core.engine.SearchPlan` — a single jitted JAX executable
(scan over the partitioned tile grid, micro-batched over queries) held in
a **process-wide plan cache** keyed by (IR structure, metric, k, tile
geometry, backend, micro-batch, shard count).  Passing ``shards=S`` to
``compile_module`` selects the multi-device executable — gallery rows
sharded over a ``("data",)`` mesh with a cross-device top-k tournament
merge — bit-identical to the single-device plan for integer metrics
(see the sharding section of ``docs/engine.md``).  Calling the returned
:class:`CompiledCamProgram` dispatches to that plan; recompiling the same
program — or sweeping DSE points that share a plan key — reuses the
cached executable instead of re-tracing.  Programs the engine cannot
express (host ops mixed in, multiple similarities) fall back to the IR
interpreter transparently; ``execute_interpreted`` always takes the
op-by-op path.  See ``docs/engine.md``.

Binary/bipolar metrics (hamming / dot / cos) execute **bit-packed** by
default: the plan stores the gallery as uint32 lanes and searches via
XOR+popcount — bit-identical results, 32x smaller resident gallery.
``compile_module(..., pack=False)`` forces the float path (and the
packing choice is part of the plan-cache key either way).

The plan cache holds a second family alongside ``SearchPlan``:
pure *range* programs (``cim.range_search`` — the paper's TH threshold
mode, or the analog-CAM interval match that carries decision-forest
inference, see ``repro.forest`` and ``docs/forest.md``) compile into a
:class:`~repro.core.engine.RangePlan` whose result is the boolean
``(M, N)`` match matrix.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .arch import ArchSpec, CamType, OptimizationTarget
from .engine import PlanBase, get_plan
from .executor import execute_module
from .ir import Module, PassManager
from .passes import (CamMap, CimToCam, CompulsoryPartition, FuseExecuteBlocks,
                     SimilarityMatching, TorchToCim)
from .passes.cam_map import MappingPlan
from .torch_dialect import trace

__all__ = ["CompiledCamProgram", "compile_module", "compile_fn", "C4CAMCompiler"]


@dataclass
class CompiledCamProgram:
    """The artifact returned by C4CAM compilation."""

    arch: ArchSpec
    cam_type: str
    stages: Dict[str, Module]
    snapshots: List[Tuple[str, str]]
    plans: List[MappingPlan]
    matched_patterns: List[str]
    backend: str = "jnp"
    engine_plan: Optional[PlanBase] = None
    shards: int = 1

    def __call__(self, *inputs):
        """Execute the program: compiled search plan when available,
        functional interpretation (host JAX simulation) otherwise."""
        if self.engine_plan is not None:
            return self.engine_plan.execute(*inputs)
        return execute_module(self.stages["cim_partitioned"], *inputs,
                              backend=self.backend)

    def execute_interpreted(self, *inputs):
        """Op-by-op interpretation (tests the explicit tiled IR)."""
        return execute_module(self.stages["cim_partitioned"], *inputs,
                              backend="jnp")

    def execute_unplanned(self, *inputs):
        """The pre-engine executor path (interpreter walk with the
        configured backend) — kept for parity tests and benchmarks."""
        return execute_module(self.stages["cim_partitioned"], *inputs,
                              backend=self.backend)

    def cost_report(self):
        from ..camsim import CostModel
        cm = CostModel(self.arch)
        return cm.report(self.plans)

    def dump(self, stage: str = "cam_mapped") -> str:
        return self.stages[stage].dump()


def compile_module(module: Module, arch: ArchSpec, *,
                   cam_type: str = CamType.TCAM,
                   target: Optional[str] = None,
                   unroll_limit: int = 64,
                   value_bits: Optional[int] = None,
                   backend: str = "jnp",
                   shards: Optional[int] = None,
                   pack: Optional[bool] = None) -> CompiledCamProgram:
    if target is not None:
        arch = arch.with_target(target)
    ctx: Dict[str, Any] = {"arch": arch, "value_bits": value_bits}
    stages: Dict[str, Module] = {"torch": module}

    pm1 = PassManager()
    pm1.add(TorchToCim())
    m = pm1.run(module.clone(), ctx)
    stages["cim"] = m.clone()

    pm2 = PassManager()
    pm2.add(FuseExecuteBlocks()).add(SimilarityMatching())
    m = pm2.run(m, ctx)
    stages["cim_fused"] = m.clone()

    pm3 = PassManager()
    pm3.add(CompulsoryPartition(unroll_limit=unroll_limit))
    m = pm3.run(m, ctx)
    stages["cim_partitioned"] = m.clone()

    pm4 = PassManager()
    pm4.add(CimToCam(cam_type=cam_type))
    m = pm4.run(m, ctx)
    stages["cam"] = m.clone()

    pm5 = PassManager(verify_each=False)   # mapped IR is loop-structured
    pm5.add(CamMap())
    m = pm5.run(m, ctx)
    stages["cam_mapped"] = m

    snapshots = (pm1.snapshots + pm2.snapshots[1:] + pm3.snapshots[1:]
                 + pm4.snapshots[1:] + pm5.snapshots[1:])
    engine_plan = get_plan(stages["cim_partitioned"], backend=backend,
                           shards=shards, pack=pack)
    return CompiledCamProgram(
        arch=arch, cam_type=cam_type, stages=stages, snapshots=snapshots,
        plans=ctx.get("plans", []),
        matched_patterns=ctx.get("matched_patterns", []),
        backend=backend, engine_plan=engine_plan,
        shards=engine_plan.shards if engine_plan is not None else 1)


def compile_fn(fn: Callable, example_inputs: Sequence[Any], arch: ArchSpec,
               **kw) -> CompiledCamProgram:
    """Trace a TorchScript-like callable and compile it (end-to-end path)."""
    return compile_module(trace(fn, example_inputs), arch, **kw)


class C4CAMCompiler:
    """Object-style front door mirroring the paper's tool (arch spec + app)."""

    def __init__(self, arch: ArchSpec, cam_type: str = CamType.TCAM,
                 backend: str = "jnp", shards: Optional[int] = None):
        self.arch = arch
        self.cam_type = cam_type
        self.backend = backend
        self.shards = shards

    def compile(self, fn: Callable, example_inputs: Sequence[Any],
                target: Optional[str] = None, **kw) -> CompiledCamProgram:
        kw.setdefault("shards", self.shards)
        return compile_fn(fn, example_inputs, self.arch,
                          cam_type=self.cam_type, target=target,
                          backend=self.backend, **kw)
