"""The ``torch`` dialect and the TorchScript-like tracing frontend.

The paper's entry point is Torch IR produced by the torch-mlir converter,
extended with the ``norm``/``topk`` primitives that stock torch-mlir lacks
(paper §III-C).  We reproduce the same surface: a tiny ``Tensor`` proxy that
records ATen-style ops while tracing a Python callable, yielding a
:class:`repro.core.ir.Module` whose ops live in the ``torch`` dialect.

Supported ops (the vocabulary Algorithm 1 needs, plus elementwise glue):

``torch.transpose``, ``torch.matmul``/``mm``, ``torch.sub``, ``torch.add``,
``torch.mul``, ``torch.div``, ``torch.norm`` (vector p-norm along a dim),
``torch.topk``, ``torch.neg``, ``torch.abs``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .ir import Builder, IRError, Module, Operation, TensorType, Value, verify

__all__ = ["TracedTensor", "trace", "TORCH_OPS"]

TORCH_OPS = {
    "torch.transpose", "torch.matmul", "torch.mm", "torch.sub", "torch.add",
    "torch.mul", "torch.div", "torch.norm", "torch.topk", "torch.neg",
    "torch.abs", "torch.unsqueeze", "torch.squeeze",
}


def _broadcast_shape(a: Tuple[int, ...], b: Tuple[int, ...]) -> Tuple[int, ...]:
    out: List[int] = []
    for da, db in zip(((1,) * (len(b) - len(a)) + a) if len(a) < len(b) else a,
                      ((1,) * (len(a) - len(b)) + b) if len(b) < len(a) else b):
        if da != db and 1 not in (da, db):
            raise IRError(f"cannot broadcast {a} with {b}")
        out.append(max(da, db))
    return tuple(out)


class TracedTensor:
    """Proxy standing in for ``torch.Tensor`` during tracing."""

    def __init__(self, value: Value, tracer: "_Tracer"):
        self.value = value
        self.tracer = tracer

    # -- shape helpers -----------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.value.type.shape

    @property
    def dtype(self) -> str:
        return self.value.type.dtype

    def _emit(self, name: str, operands: Sequence["TracedTensor"],
              out_shapes: Sequence[Tuple[int, ...]],
              attrs: Optional[Dict[str, Any]] = None,
              dtypes: Optional[Sequence[str]] = None):
        dts = dtypes or [self.dtype] * len(out_shapes)
        op = self.tracer.builder.create(
            name, [t.value for t in operands],
            [TensorType(s, d) for s, d in zip(out_shapes, dts)], attrs or {})
        outs = [TracedTensor(r, self.tracer) for r in op.results]
        return outs[0] if len(outs) == 1 else tuple(outs)

    # -- ATen-style ops ------------------------------------------------------
    def transpose(self, dim0: int = -2, dim1: int = -1) -> "TracedTensor":
        shape = list(self.shape)
        d0, d1 = dim0 % len(shape), dim1 % len(shape)
        shape[d0], shape[d1] = shape[d1], shape[d0]
        return self._emit("torch.transpose", [self], [tuple(shape)],
                          {"dim0": dim0, "dim1": dim1})

    def matmul(self, other: "TracedTensor") -> "TracedTensor":
        a, b = self.shape, other.shape
        if a[-1] != b[-2]:
            raise IRError(f"matmul mismatch {a} @ {b}")
        batch = _broadcast_shape(a[:-2], b[:-2]) if len(a) > 2 or len(b) > 2 else ()
        return self._emit("torch.matmul", [self, other], [batch + (a[-2], b[-1])])

    mm = matmul
    __matmul__ = matmul

    def _binary(self, name: str, other: "TracedTensor") -> "TracedTensor":
        return self._emit(name, [self, other],
                          [_broadcast_shape(self.shape, other.shape)])

    def sub(self, other: "TracedTensor") -> "TracedTensor":
        return self._binary("torch.sub", other)

    def add(self, other: "TracedTensor") -> "TracedTensor":
        return self._binary("torch.add", other)

    def mul(self, other: "TracedTensor") -> "TracedTensor":
        return self._binary("torch.mul", other)

    def div(self, other: "TracedTensor") -> "TracedTensor":
        return self._binary("torch.div", other)

    __sub__ = sub
    __add__ = add
    __mul__ = mul
    __truediv__ = div

    def unsqueeze(self, dim: int) -> "TracedTensor":
        d = dim % (len(self.shape) + 1)
        shape = self.shape[:d] + (1,) + self.shape[d:]
        return self._emit("torch.unsqueeze", [self], [shape], {"dim": d})

    def squeeze(self, dim: int) -> "TracedTensor":
        d = dim % len(self.shape)
        if self.shape[d] != 1:
            raise IRError(f"squeeze of non-1 dim {d} of {self.shape}")
        shape = self.shape[:d] + self.shape[d + 1:]
        return self._emit("torch.squeeze", [self], [shape], {"dim": d})

    def neg(self) -> "TracedTensor":
        return self._emit("torch.neg", [self], [self.shape])

    def abs(self) -> "TracedTensor":
        return self._emit("torch.abs", [self], [self.shape])

    def norm(self, p: int = 2, dim: int = -1, keepdim: bool = False) -> "TracedTensor":
        d = dim % len(self.shape)
        shape = tuple(s for i, s in enumerate(self.shape) if i != d) \
            if not keepdim else tuple(1 if i == d else s for i, s in enumerate(self.shape))
        return self._emit("torch.norm", [self], [shape],
                          {"p": p, "dim": dim, "keepdim": keepdim})

    def topk(self, k: int, dim: int = -1, largest: bool = True,
             sorted: bool = True) -> Tuple["TracedTensor", "TracedTensor"]:
        d = dim % len(self.shape)
        shape = tuple(k if i == d else s for i, s in enumerate(self.shape))
        return self._emit("torch.topk", [self], [shape, shape],
                          {"k": k, "dim": dim, "largest": largest, "sorted": sorted},
                          dtypes=[self.dtype, "i32"])


class _Tracer:
    def __init__(self, module: Module):
        self.module = module
        self.builder = Builder(module.body)


def trace(fn: Callable[..., Any], example_inputs: Sequence[Any],
          name: Optional[str] = None, dtype: str = "f32") -> Module:
    """Trace ``fn`` (taking/returning TracedTensors) into a torch-dialect Module.

    ``example_inputs`` may be numpy arrays, ShapeDtypeStruct-likes (anything
    with ``.shape``), or plain shape tuples.
    """

    def shape_of(x: Any) -> Tuple[int, ...]:
        if isinstance(x, tuple) and all(isinstance(d, int) for d in x):
            return x
        return tuple(int(d) for d in x.shape)

    def dtype_of(x: Any) -> str:
        dt = getattr(x, "dtype", None)
        if dt is None:
            return dtype
        dt = np.dtype(dt) if not isinstance(dt, str) else np.dtype(dt)
        return {"float32": "f32", "float64": "f64", "int32": "i32",
                "int64": "i64", "int8": "i8", "uint8": "ui8",
                "bool": "i1", "float16": "f16", "bfloat16": "bf16"}.get(dt.name, "f32")

    arg_types = [TensorType(shape_of(x), dtype_of(x)) for x in example_inputs]
    module = Module(name or getattr(fn, "__name__", "traced"), arg_types,
                    arg_names=[f"arg{i}" for i in range(len(arg_types))])
    tracer = _Tracer(module)
    inputs = [TracedTensor(v, tracer) for v in module.arguments]
    out = fn(*inputs)
    outs = out if isinstance(out, (tuple, list)) else (out,)
    flat: List[Value] = []
    for o in outs:
        if not isinstance(o, TracedTensor):
            raise IRError(f"traced function returned non-tensor {o!r}")
        flat.append(o.value)
    tracer.builder.ret(flat)
    verify(module)
    return module
