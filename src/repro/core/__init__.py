"""C4CAM core: the paper's compiler, reproduced on a JAX substrate.

Public API::

    from repro.core import (ArchSpec, C4CAMCompiler, compile_fn, trace,
                            CamType, OptimizationTarget, PAPER_BASE_ARCH)

    arch = PAPER_BASE_ARCH.with_target("power")
    prog = compile_fn(hdc_similarity, [queries, classes], arch)
    values, indices = prog(queries, classes)     # functional CAM simulation
    report = prog.cost_report()                  # latency / energy / power
"""

from .arch import (AccessMode, ArchSpec, CamType, Metric, OptimizationTarget,
                   PAPER_BASE_ARCH, SearchType, kazemi_arch)
from .compiler import C4CAMCompiler, CompiledCamProgram, compile_fn, compile_module
from .engine import (CompositePlan, HierarchicalPlan, HierarchicalSpec,
                     PendingSearch, PlanBase, RangePlan, RangeSpec,
                     SearchPlan, SimilaritySpec, clear_plan_cache,
                     get_hierarchical_plan, get_plan,
                     merge_shard_candidates, plan_cache_stats, spec_digest,
                     workload_digest)
from .ir import Block, Builder, IRError, Module, Operation, Pass, PassManager, TensorType, Value, verify
from .torch_dialect import TracedTensor, trace

__all__ = [
    "AccessMode", "ArchSpec", "CamType", "Metric", "OptimizationTarget",
    "PAPER_BASE_ARCH", "SearchType", "kazemi_arch",
    "C4CAMCompiler", "CompiledCamProgram", "compile_fn", "compile_module",
    "CompositePlan", "HierarchicalPlan", "HierarchicalSpec",
    "PendingSearch", "PlanBase", "RangePlan", "RangeSpec", "SearchPlan",
    "SimilaritySpec", "clear_plan_cache",
    "get_hierarchical_plan", "get_plan",
    "merge_shard_candidates", "plan_cache_stats",
    "spec_digest", "workload_digest",
    "Block", "Builder", "IRError", "Module", "Operation", "Pass",
    "PassManager", "TensorType", "Value", "verify",
    "TracedTensor", "trace",
]
