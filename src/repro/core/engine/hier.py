"""Hierarchical two-stage CAM search: :class:`HierarchicalPlan`.

The CAM analogue of an IVF index, built on the plan-graph layer
(:mod:`.composite`).  ``prepare`` clusters the gallery rows with a
seeded k-means and lays each cluster out on its own group of row tiles;
at dispatch time a *coarse* :class:`~.plans.SearchPlan` over the
cluster centroids selects the ``nprobe`` most promising clusters per
query, and the *fine* probing executable searches only those clusters'
tiles — activating ``~nprobe / clusters`` of the crossbar array instead
of all of it (the paper's energy argument for hierarchical search:
match-line precharge is the dominant per-query cost, and it scales with
the number of searched subarrays).

Correctness contract
--------------------

The fine stage selects candidates by the composite key **(physical
value, global row id)** — a stable ``lax.sort`` with ``num_keys=2`` —
which is exactly the order the flat tile tournament resolves ties in
(stable per-tile ``lax.top_k`` + ascending-row-offset merges).  Row
placement inside the cluster tiles is therefore irrelevant to the
result: any probe schedule that covers the true top-k rows returns
bit-identical output to the flat plan (integer metrics; eucl keeps the
repo-wide float-tolerance contract).  Consequences:

* ``nprobe == clusters`` probes everything → bit-identical to the flat
  plan, sharded or not, packed or not.  (One dead-slot caveat: when
  fewer than k rows exist/are probed, the losing slots carry the
  ``2**30`` sentinel index here, while the flat tournament may report
  ragged in-extent positions — same losing values, geometry-dependent
  filler indices.  Winning slots always match exactly.)
* ``update_rows`` may place a moved row in *any* free slot of its new
  cluster — results match a full re-layout with the same centroids
  bit-for-bit, so the incremental path needs no compensation logic.
* Centroids are **fixed** across ``update_rows`` (k-means runs once per
  prepared gallery).  A mutated row is reassigned to its nearest stored
  centroid; if its new cluster's tiles are full, the whole layout is
  rebuilt (same centroids, fresh uniform tiles-per-cluster).

Sharding splits the *fine tile axis* over the device mesh: each device
holds ``1/shards`` of the cluster tiles and probes only the candidate
tiles it owns (gathers into its local shard; foreign candidates mask to
sentinels).  Per-device candidate lists merge host-side by the same
composite key (:func:`_merge_hier_shards` — a lexsort, because probing
order is not ascending-row order like the flat shard merge).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec

from ...launch.mesh import make_data_mesh
from ...obs.trace import trace_span, tracer
from ..envcfg import env_int
from .base import _pick_batch, _size
from .cache import _lookup_or_insert, _normalize_shards, get_plan
from .composite import CompositePlan, HierarchicalSpec
from .executables import _lay_patterns, _layout_queries
from .plans import SearchPlan
from .spec import (SimilaritySpec, _PACKABLE_METRICS, _bits, _metric_values,
                   _resolve_pack, extract_plan_spec, module_for_spec)

__all__ = ["HierarchicalPlan", "get_hierarchical_plan"]

#: sentinel global row id for empty tile slots / losing candidates —
#: the same value the flat executables' ``pad_candidates`` emits, so a
#: hierarchical result is indistinguishable from a flat one
_SENT = 2 ** 30


# ---------------------------------------------------------------------------
# Clustering (host-driven, jnp matmuls): seeded k-means + assignment
# ---------------------------------------------------------------------------


def _enc_f32(x, metric: str) -> jax.Array:
    """Rows in the clustering space: cell bits (as {0,1} float32) for
    the packable metrics — their physical search is Hamming on bits —
    raw float32 values for eucl."""
    if metric in _PACKABLE_METRICS:
        return _bits(jnp.asarray(x), metric).astype(jnp.float32)
    return jnp.asarray(x).astype(jnp.float32)


def _argmin_assign(rows: jax.Array, cent: jax.Array, metric: str) -> jax.Array:
    """Nearest stored centroid per row, ties to the lower centroid id.

    Distances via matmul (fast at gallery scale): Hamming between bit
    vectors is ``b @ (1-c)^T + (1-b) @ c^T`` — exact integers in f32 —
    and eucl uses the same expansion as the kernels.  ``argmin`` picks
    the first minimum, which makes assignment deterministic.
    """
    if metric in _PACKABLE_METRICS:
        d = rows @ (1.0 - cent).T + (1.0 - rows) @ cent.T
    else:
        qq = (rows * rows).sum(-1, keepdims=True)
        cc = (cent * cent).sum(-1)
        d = qq + cc[None, :] - 2.0 * (rows @ cent.T)
    return jnp.argmin(d, axis=1).astype(jnp.int32)


def _assign_rows(rows_raw, cent_src, metric: str) -> jax.Array:
    """Assignment of raw-domain rows against the stored raw-domain
    centroids (used by ``update_rows`` reassignment)."""
    return _argmin_assign(_enc_f32(rows_raw, metric),
                          _enc_f32(cent_src, metric), metric)


def _kmeans(g: jax.Array, spec_h: HierarchicalSpec
            ) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded Lloyd k-means over the encoded gallery.

    Returns ``(centroids, assign)`` — centroids in the *raw input
    domain* (binarised {0,1} cells for the packable metrics, float
    means for eucl) so they can be stored directly as the coarse
    plan's gallery, and the final per-row cluster assignment.
    Deterministic: seeded init (distinct rows), first-minimum ties,
    mean-threshold binarisation; empty clusters keep their previous
    centroid.
    """
    fine = spec_h.fine
    n, clusters = fine.n, spec_h.clusters
    metric = fine.metric
    enc = _enc_f32(g, metric)
    rng = np.random.default_rng(spec_h.seed)
    cent = enc[jnp.asarray(rng.choice(n, size=clusters, replace=False))]
    ones = jnp.ones((n,), jnp.float32)
    binary = metric in _PACKABLE_METRICS
    for _ in range(spec_h.kmeans_iters):
        a = _argmin_assign(enc, cent, metric)
        sums = jax.ops.segment_sum(enc, a, num_segments=clusters)
        cnt = jax.ops.segment_sum(ones, a, num_segments=clusters)
        mean = sums / jnp.maximum(cnt, 1.0)[:, None]
        newc = (mean > 0.5).astype(jnp.float32) if binary else mean
        cent = jnp.where((cnt > 0.0)[:, None], newc, cent)
    a = _argmin_assign(enc, cent, metric)
    return np.asarray(cent), np.asarray(a, np.int32)


# ---------------------------------------------------------------------------
# Layout: cluster assignment -> per-cluster tile groups
# ---------------------------------------------------------------------------


def _layout_from_assign(assign: np.ndarray, clusters: int, tr: int,
                        n: int) -> Tuple[np.ndarray, np.ndarray, int,
                                         np.ndarray]:
    """Uniform tiles-per-cluster slot layout from an assignment.

    Every cluster gets ``tpc = ceil(max_cluster_size / tile_rows)``
    tiles (uniform so a probe step is a static-shape gather: candidate
    tile ids are just ``cluster * tpc + j``).  Rows land in their
    cluster's slots in ascending global-id order; empty slots carry the
    ``_SENT`` row id.  Returns ``(row_ids (T, tr), slot_of (n,), tpc,
    cnt (clusters,))`` where ``cnt[c]`` is the *occupied tile prefix*
    of cluster ``c`` — k-means clusters are imbalanced, so most
    clusters fill far fewer than ``tpc`` tiles and the probe skips the
    all-sentinel remainder (see :func:`_probe_budget`).
    """
    counts = np.bincount(assign, minlength=clusters)
    tpc = max(1, int(-(-int(counts.max()) // tr))) if n else 1
    cap = tpc * tr
    flat = np.full(clusters * cap, _SENT, np.int32)
    order = np.argsort(assign, kind="stable")
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    pos = np.arange(n, dtype=np.int64) - starts[assign[order]]
    slot = assign[order].astype(np.int64) * cap + pos
    flat[slot] = order.astype(np.int32)
    slot_of = np.empty(n, np.int64)
    slot_of[order] = slot
    cnt = (-(-counts // tr)).astype(np.int32)       # rows fill a prefix
    return flat.reshape(clusters * tpc, tr), slot_of, tpc, cnt


def _probe_budget(cnt: np.ndarray, nprobe: int, tpc: int) -> int:
    """Static per-query probe-step budget: the worst case any query can
    need is the ``nprobe`` largest occupied-tile prefixes — data
    dependent on the *gallery* (known at prepare time), never on the
    queries, so the probe jit stays query-shape-static.  Rounded up to
    a multiple of 16 steps so small occupancy drift under
    ``update_rows`` does not retrace, capped at the padded
    ``nprobe * tpc`` it replaces.

    Trace-motivated (ROADMAP item 1): at the bench geometry the
    largest cluster forces ``tpc = 26`` while the mean occupancy is
    ~8.5 tiles, so the padded schedule ran 416 probe steps/query where
    the top-16 occupancy sum needs 235 — the gather, distance and
    select stages all shrank proportionally (probe 191 ms -> 99 ms,
    bit-identical output).
    """
    top = np.sort(cnt)[::-1][:nprobe]
    nb = int(top.sum())
    nb = -(-max(nb, 1) // 16) * 16
    return max(1, min(nprobe * tpc, nb))


def _leaves_from_rows(g: jax.Array, row_ids: np.ndarray,
                      fine: SimilaritySpec, packed: bool) -> Tuple:
    """Fine tile leaves from the slot layout: gather rows by slot
    (empty slots become zero rows, which every cell encoding preserves)
    and run the standard pattern layout on the permuted gallery."""
    t, tr = row_ids.shape
    flat = jnp.asarray(row_ids.reshape(-1))
    valid = flat < _SENT
    rows = jnp.asarray(g)[jnp.clip(flat, 0, fine.n - 1)]
    rows = jnp.where(valid[:, None], rows, 0)
    lspec = replace(fine, n=t * tr, grid_rows=t)
    return _lay_patterns(rows, None, lspec, t, packed)


@dataclass
class HierState:
    """A prepared hierarchical gallery (one pattern-memo entry).

    Device state: the centroid gallery + its coarse-prepared leaves,
    the fine tile leaves and the device slot->row-id map.  Host state:
    the assignment / slot bookkeeping ``update_rows`` rewrites (master
    copies — the incremental path copies before mutating so an older
    memo entry never sees a newer layout).
    """

    centroid_src: jax.Array            # (clusters, dim) raw-domain
    coarse_prepared: Any               # coarse plan's prepared leaves
    leaves: Tuple[jax.Array, ...]      # ((T[+pad], gc, tr, X),)
    row_ids: jax.Array                 # (T[+pad], tr) int32, device
    assign: np.ndarray                 # (n,) int32
    slot_of: np.ndarray                # (n,) int64 flat slot index
    row_ids_h: np.ndarray              # (T, tr) int32, host master
    tpc: int                           # tiles per cluster
    cnt: jax.Array                     # (clusters,) occupied tile prefix
    cnt_h: np.ndarray                  # host master of ``cnt``
    budget: int                        # static probe steps per query


# ---------------------------------------------------------------------------
# Fine probing executables
# ---------------------------------------------------------------------------


def _batched_col_dist(fine: SimilaritySpec, packed: bool):
    """Per-query-tile partial distance: ``f(qc, pt) -> (B, tr)`` where
    *each query has its own tile* (``pt``: (B, tr, X)).  Same arithmetic
    as the flat per-tile kernels — broadcast mismatch counts / packed
    popcounts are exact integers, eucl uses the identical expansion —
    so the probed values equal the flat tournament's values.
    """
    phys_metric, _, _ = _metric_values(fine.metric, fine.largest)
    if packed:
        from ...kernels.packing import popcount32

        def fp(qc, pt):
            return popcount32(qc[:, None, :] ^ pt).sum(-1) \
                .astype(jnp.float32)
        return fp
    if phys_metric == "hamming":
        return lambda qc, pt: (qc[:, None, :] != pt).sum(-1) \
            .astype(jnp.float32)

    def fe(qc, pt):
        qq = (qc * qc).sum(-1)
        pp = (pt * pt).sum(-1)
        return qq[:, None] + pp - 2.0 * jnp.einsum("bd,btd->bt", qc, pt)
    return fe


#: per-group gather budget (array elements): probe steps are grouped so
#: one composite-key sort covers many candidate tiles — one tile per
#: sort is launch/sort-overhead-bound and loses the probing win — while
#: the gathered group buffer stays bounded (~64 MB at 4 B/element)
_GROUP_BUDGET = 1 << 24

#: gid width (rows) under which the top-k fast path may encode row ids
#: as float32 exactly (24-bit mantissa; the ``2**30`` sentinel is a
#: power of two and stays exact too)
_TOPK_GID_EXACT = 1 << 24


def _composite_select(k: int, lose, exact_gids: bool):
    """``select(skeys, gids, vals) -> (k smallest by (skey, gid))``.

    The reference implementation is one stable two-key ``lax.sort`` —
    but a full-width variadic sort is the single most expensive op in
    the probe (slower than the whole flat scan at bench geometry).  When
    every gid is float32-exact the same selection runs as two
    ``lax.top_k`` passes + one 3k-wide cleanup sort:

    * pass 1 (top-k on the scalar key) covers every entry *strictly*
      better than the k-th smallest key ``tau`` — at most k-1 of them,
      so none is lost to top-k's positional tie-break;
    * pass 2 (top-k on ``-gid`` where ``skey == tau``) picks the
      smallest-gid entries at ``tau``, exactly the composite order;
    * entries dropped from pass 1 (``skey == tau``, wrong tie choice)
      mask to the sentinel triple and the survivors merge in one tiny
      stable sort.

    ``tau`` must come from a *reduction* over the top-k values, never a
    slice: ``nv[:, k-1:k]`` folds into top_k's internal sort+slice
    pattern and stops XLA's TopK rewrite from firing on CPU (a ~50x
    regression back to the full sort).
    """
    def by_sort(ks, kg, kd):
        ks, kg, kd = jax.lax.sort((ks, kg, kd), dimension=-1,
                                  is_stable=True, num_keys=2)
        return ks[:, :k], kg[:, :k], kd[:, :k]

    if not exact_gids:
        return by_sort

    def by_topk(ks, kg, kd):
        nv, idx = jax.lax.top_k(-ks, k)
        tau = -jnp.min(nv, axis=-1, keepdims=True)
        sk = jnp.take_along_axis(ks, idx, axis=-1)
        sg = jnp.take_along_axis(kg, idx, axis=-1)
        sv = jnp.take_along_axis(kd, idx, axis=-1)
        strict = sk < tau
        sk = jnp.where(strict, sk, jnp.inf)
        sg = jnp.where(strict, sg, _SENT)
        sv = jnp.where(strict, sv, lose)
        gf = kg.astype(jnp.float32)
        tv, tidx = jax.lax.top_k(jnp.where(ks == tau, -gf, -jnp.inf), k)
        tie = tv > -jnp.inf
        tk = jnp.where(tie, jnp.broadcast_to(tau, tv.shape), jnp.inf)
        tg = jnp.where(tie, (-tv).astype(jnp.int32), _SENT)
        tvv = jnp.where(tie, jnp.take_along_axis(kd, tidx, axis=-1), lose)
        return by_sort(jnp.concatenate([sk, tk], axis=-1),
                       jnp.concatenate([sg, tg], axis=-1),
                       jnp.concatenate([sv, tvv], axis=-1))

    return by_topk


def _step_to_tile(s, pre, nprobe: int):
    """Map one probe step to its per-query (probe rank, tile offset).

    ``pre`` (B, nprobe+1) is the per-query inclusive prefix sum of the
    probed clusters' occupied-tile counts: step ``s`` belongs to the
    probe rank whose prefix window contains it, at offset ``s`` minus
    the window start.  Steps past ``pre[:, -1]`` are dead padding (the
    static budget covers the worst-case query; most need fewer).
    """
    p = jnp.sum(s >= pre[:, 1:], axis=1)
    p = jnp.minimum(p, nprobe - 1)
    j = s - jnp.take_along_axis(pre, p[:, None], axis=1)[:, 0]
    live = s < pre[:, -1]
    return p, j, live


def _probe_prefix(ci, cnt):
    """Per-query prefix sums of the probed clusters' occupied-tile
    counts: ``(B, nprobe+1)`` int32, leading zero column."""
    pc = cnt[ci]
    return jnp.concatenate(
        [jnp.zeros((ci.shape[0], 1), jnp.int32),
         jnp.cumsum(pc, axis=1, dtype=jnp.int32)], axis=1)


def _probe_steps(spec_h: HierarchicalSpec, packed: bool):
    """The candidate-tile scan shared by the single-device and sharded
    probes: ``steps(qt, gather, bsz, total)`` folds ``total`` probe
    steps (the occupancy budget from :func:`_probe_budget`), where
    ``gather(s) -> (tile_leaf (B, gc, tr, X), row_ids (B, tr))`` is the
    backend-specific candidate fetch.

    Steps run in *groups*: each ``lax.scan`` iteration gathers ``G``
    candidate tiles per query and folds all ``G * tile_rows``
    candidates through one composite-key selection (physical value,
    global row id — the flat tournament's tie order, see
    :func:`_composite_select`) truncated to k.  ``G`` is the largest group whose gathered slab fits
    ``_GROUP_BUDGET`` elements, so small plans collapse to a single
    sort while huge ones keep bounded memory.  Padded trailing steps
    (when the group size does not divide the step count) mask their
    row ids to the sentinel, never duplicating a candidate.
    """
    fine = spec_h.fine
    _, _, phys_largest = _metric_values(fine.metric, fine.largest)
    tr, k = fine.tile_rows, fine.k
    lose = -jnp.inf if phys_largest else jnp.inf
    col = _batched_col_dist(fine, packed)
    select = _composite_select(k, lose, fine.n < _TOPK_GID_EXACT)
    #: per-column slab width (elements) of one gathered tile row
    wpr = fine.grid_cols * (-(-fine.dims_per_tile // 32) if packed
                            else fine.dims_per_tile)

    def run(qt, gather, bsz, total):
        per_tile = max(1, bsz * tr * wpr)
        g = max(1, min(total, _GROUP_BUDGET // per_tile))
        ngroups = -(-total // g)
        steps = jnp.arange(ngroups * g).reshape(ngroups, g)

        init = (jnp.full((bsz, k), jnp.inf, jnp.float32),
                jnp.full((bsz, k), _SENT, jnp.int32),
                jnp.full((bsz, k), lose, jnp.float32))

        def tile_dist(pt):                       # (B, gc, tr, X) -> (B, tr)
            def cstep(acc, xs):
                return acc + col(xs[0], xs[1]), None

            d, _ = jax.lax.scan(cstep, jnp.zeros((bsz, tr), jnp.float32),
                                (qt, pt.transpose(1, 0, 2, 3)))
            return d

        def step(carry, ss):                     # ss: (G,) step indices
            pt, rg = jax.vmap(gather)(ss)        # (G,B,gc,tr,X), (G,B,tr)
            rg = jnp.where((ss < total)[:, None, None], rg, _SENT)
            dist = jax.vmap(tile_dist)(pt)       # (G, B, tr)
            dist = dist.transpose(1, 0, 2).reshape(bsz, -1)
            rg = rg.transpose(1, 0, 2).reshape(bsz, -1)
            valid = rg < _SENT
            sk = jnp.where(valid, -dist if phys_largest else dist, jnp.inf)
            dd = jnp.where(valid, dist, lose)
            return select(jnp.concatenate([carry[0], sk], axis=-1),
                          jnp.concatenate([carry[1], rg], axis=-1),
                          jnp.concatenate([carry[2], dd], axis=-1)), None

        (_, kg, kd), _ = jax.lax.scan(step, init, steps)
        return kd, kg

    return run


def _hier_probe(spec_h: HierarchicalSpec, packed: bool):
    """Single-device fine probe: ``probe(q, ci, leaf, rid, cnt, tpc,
    budget)`` -> logical ``(values, indices)``.  ``tpc`` and ``budget``
    are static (the jit retraces when an overflow re-layout changes the
    tiles-per-cluster, or occupancy drift moves the bucketed budget).

    Each step probes one *occupied* tile of one probed cluster: the
    per-query prefix map (:func:`_step_to_tile`) packs the ragged
    per-cluster tile lists into a dense static schedule, so imbalanced
    clusters no longer pay the padded worst case.
    """
    fine = spec_h.fine
    nprobe = spec_h.nprobe
    _, to_logical, _ = _metric_values(fine.metric, fine.largest)
    run = _probe_steps(spec_h, packed)

    def probe(q, ci, leaf, rid, cnt, tpc, budget):
        qt = _layout_queries(q, fine, packed)
        pre = _probe_prefix(ci, cnt)

        def gather(s):
            p, j, live = _step_to_tile(s, pre, nprobe)
            c = jnp.take_along_axis(ci, p[:, None], axis=1)[:, 0]
            tile = jnp.clip(c * tpc + j, 0, leaf.shape[0] - 1)
            rg = jnp.where(live[:, None], rid[tile], _SENT)
            return leaf[tile], rg

        kd, kg = run(qt, gather, q.shape[0], budget)
        return to_logical(kd, float(fine.dim)), kg

    return jax.jit(probe, static_argnums=(5, 6))


def _hier_probe_sharded(spec_h: HierarchicalSpec, packed: bool,
                        shards: int, mesh):
    """Sharded fine probe: the tile axis lives on the mesh, each device
    gathers only the candidate tiles it owns (foreign candidates mask to
    sentinels) and emits its own (B, k) candidate list; the cross-shard
    composite-key merge happens host-side in :func:`_merge_hier_shards`."""
    fine = spec_h.fine
    nprobe = spec_h.nprobe
    _, to_logical, _ = _metric_values(fine.metric, fine.largest)
    run = _probe_steps(spec_h, packed)

    def probe(q, ci, leaf, rid, cnt, tpc, budget):
        qt = _layout_queries(q, fine, packed)
        bsz = q.shape[0]

        def local(qt_l, ci_l, leaf_l, rid_l, cnt_l):
            d = jax.lax.axis_index("data")
            tps = leaf_l.shape[0]
            pre = _probe_prefix(ci_l, cnt_l)

            def gather(s):
                p, j, live = _step_to_tile(s, pre, nprobe)
                c = jnp.take_along_axis(ci_l, p[:, None], axis=1)[:, 0]
                tile = c * tpc + j
                loc = tile - d * tps
                inr = live & (loc >= 0) & (loc < tps)
                locc = jnp.clip(loc, 0, tps - 1)
                rg = jnp.where(inr[:, None], rid_l[locc], _SENT)
                return leaf_l[locc], rg

            kd, kg = run(qt_l, gather, bsz, budget)
            return to_logical(kd, float(fine.dim))[None], kg[None]

        return shard_map(
            local, mesh=mesh,
            in_specs=(PartitionSpec(), PartitionSpec(),
                      PartitionSpec("data"), PartitionSpec("data"),
                      PartitionSpec()),
            out_specs=(PartitionSpec("data"), PartitionSpec("data")),
            check_rep=False)(qt, ci, leaf, rid, cnt)         # (S, B, k)

    return jax.jit(probe, static_argnums=(5, 6))


def _merge_hier_shards(values, indices, *, k: int,
                       largest: bool) -> Tuple[np.ndarray, np.ndarray]:
    """Cross-shard composite-key merge for hierarchical candidates.

    Unlike :func:`~.executables.merge_shard_candidates` (where shard
    order *is* ascending global-row order, so a stable value sort
    suffices), hierarchical shards hold permuted rows — the tie-break
    must be the explicit global row id.  A lexsort on (value key, row
    id) reproduces the flat tournament's selection exactly; no
    arithmetic happens, so integer-metric results stay bit-identical.
    """
    av = np.asarray(values)
    ai = np.asarray(indices)
    s, b, kk = av.shape
    vv = np.transpose(av, (1, 0, 2)).reshape(b, s * kk)
    ii = np.transpose(ai, (1, 0, 2)).reshape(b, s * kk)
    key = -vv if largest else vv
    sel = np.lexsort((ii, key), axis=-1)[:, :k]
    return (np.take_along_axis(vv, sel, axis=-1),
            np.take_along_axis(ii, sel, axis=-1))


# ---------------------------------------------------------------------------
# Executable builder: (prepare, chunk_fn, row_update)
# ---------------------------------------------------------------------------


def _build_hier_executable(spec_h: HierarchicalSpec, coarse: SearchPlan,
                           batch: int, shards: int, packed: bool):
    """The hierarchical (prepare, chunk_fn, row_update) triple.

    ``prepare`` runs host-side (k-means + layout are data-dependent
    host work; it executes once per gallery behind the pattern memo).
    ``chunk_fn`` composes the coarse plan's jitted chunk executable
    with the jitted fine probe — two async device calls, no host
    synchronisation between the stages.  ``row_update`` is the
    reassigning incremental relay described on :class:`HierState`.
    """
    fine = spec_h.fine
    tr = fine.tile_rows
    mesh = make_data_mesh(shards) if shards > 1 else None
    placement = NamedSharding(mesh, PartitionSpec("data")) if mesh else None
    probe = _hier_probe_sharded(spec_h, packed, shards, mesh) if mesh \
        else _hier_probe(spec_h, packed)

    def materialise(g, row_h):
        """Device leaves + device row-id map from a host slot layout."""
        leaves = _leaves_from_rows(g, row_h, fine, packed)
        if placement is None:
            return leaves, jnp.asarray(row_h)
        t = row_h.shape[0]
        tps = -(-t // shards)
        pad_t = shards * tps - t
        if pad_t:
            leaves = tuple(
                jnp.pad(x, ((0, pad_t),) + ((0, 0),) * (x.ndim - 1))
                for x in leaves)
        rid = np.full((shards * tps, tr), _SENT, np.int32)
        rid[:t] = row_h
        return (tuple(jax.device_put(x, placement) for x in leaves),
                jax.device_put(jnp.asarray(rid), placement))

    def fresh_state(g, cent_src, cpp, assign):
        row_h, slot_of, tpc, cnt_h = _layout_from_assign(
            assign, spec_h.clusters, tr, fine.n)
        leaves, rid = materialise(g, row_h)
        return HierState(centroid_src=cent_src, coarse_prepared=cpp,
                         leaves=leaves, row_ids=rid, assign=assign,
                         slot_of=slot_of, row_ids_h=row_h, tpc=tpc,
                         cnt=jnp.asarray(cnt_h), cnt_h=cnt_h,
                         budget=_probe_budget(cnt_h, spec_h.nprobe, tpc))

    def prepare(gallery):
        g = jnp.asarray(gallery)
        cent, assign = _kmeans(g, spec_h)
        cent_src = jnp.asarray(cent)
        cpp = coarse._prepared_patterns(cent_src)
        return fresh_state(g, cent_src, cpp, assign)

    def chunk_fn(q, hs):
        # under tracing each stage blocks on its device result so the
        # span durations attribute real stage time instead of jax's
        # async dispatch latency (the stages are data-dependent anyway,
        # so blocking costs pipelining only across chunk boundaries)
        with trace_span("hier.coarse"):
            _, ci = coarse._chunk_fn(q, hs.coarse_prepared)
            if tracer.enabled:
                ci.block_until_ready()
        with trace_span("hier.probe",
                        args=None if not tracer.enabled else
                        {"budget": hs.budget, "tpc": hs.tpc}):
            out = probe(q, ci, hs.leaves[0], hs.row_ids, hs.cnt,
                        hs.tpc, hs.budget)
            if tracer.enabled:
                jax.block_until_ready(out)
            return out

    # -- incremental row update -------------------------------------------

    def relay(leaves, rid, g, tiles):
        """Re-lay the touched tiles from the (mutated) gallery through
        the *new* slot map and scatter them into the prepared leaves —
        the same encode/pack/layout code a full prepare runs, on a
        ``len(tiles)``-tile slice (static length: retraces per touched
        tile count, like the flat relay)."""
        nt = tiles.shape[0]
        rg = rid[tiles].reshape(-1)
        valid = rg < _SENT
        rows = jnp.asarray(g)[jnp.clip(rg, 0, fine.n - 1)]
        rows = jnp.where(valid[:, None], rows, 0)
        lspec = replace(fine, n=nt * tr, grid_rows=nt)
        fresh = _lay_patterns(rows, None, lspec, nt, packed)
        return tuple(x.at[tiles].set(f.astype(x.dtype))
                     for x, f in zip(leaves, fresh))

    relay_jit = jax.jit(relay)
    relay_don = jax.jit(relay, donate_argnums=0)

    def rid_device(row_h):
        if placement is None:
            return jnp.asarray(row_h)
        t = row_h.shape[0]
        tps = -(-t // shards)
        rid = np.full((shards * tps, tr), _SENT, np.int32)
        rid[:t] = row_h
        return jax.device_put(jnp.asarray(rid), placement)

    def row_update(hs, new_srcs, idx, donate=False):
        g_new = jnp.asarray(new_srcs[0])
        idxa = np.asarray(idx, np.int64)
        a_new = np.asarray(_assign_rows(g_new[jnp.asarray(idxa)],
                                        hs.centroid_src, fine.metric),
                           np.int32)
        assign = hs.assign.copy()
        slot_of = hs.slot_of.copy()
        row_h = hs.row_ids_h.copy()
        flat = row_h.reshape(-1)
        cap = hs.tpc * tr
        touched = set((slot_of[idxa] // tr).tolist())
        moved_clusters = set()
        overflow = False
        for r, c_new in zip(idxa.tolist(), a_new.tolist()):
            c_old = int(assign[r])
            if c_new == c_old:
                continue                      # content change, same cluster
            s_old = int(slot_of[r])
            flat[s_old] = _SENT               # vacate the old slot
            seg = flat[c_new * cap:(c_new + 1) * cap]
            free = np.flatnonzero(seg == _SENT)
            if free.size == 0:
                overflow = True
                break
            s_new = c_new * cap + int(free[0])
            flat[s_new] = r
            slot_of[r] = s_new
            assign[r] = c_new
            touched.add(s_old // tr)
            touched.add(s_new // tr)
            moved_clusters.add(c_old)
            moved_clusters.add(c_new)
        if overflow:
            # the moved row's cluster is full: rebuild the whole layout
            # with the SAME centroids and a fresh uniform tpc.  Slot
            # placement is result-irrelevant (composite-key selection),
            # so this stays bit-identical to the incremental path.
            fresh_assign = hs.assign.copy()
            fresh_assign[idxa] = a_new
            return fresh_state(g_new, hs.centroid_src, hs.coarse_prepared,
                               fresh_assign)
        rid = rid_device(row_h)
        tiles = jnp.asarray(sorted(touched), jnp.int32)
        fn = relay_don if donate else relay_jit
        leaves = fn(tuple(hs.leaves), rid, g_new, tiles)
        if placement is not None:
            leaves = tuple(jax.device_put(x, placement) for x in leaves)
        # occupancy maintenance: a moved row can extend its new
        # cluster's occupied prefix or (with holes filled later) let an
        # old one shrink — recompute the prefix for touched clusters
        # from the highest occupied slot, so probing [0, cnt) always
        # covers every live row
        cnt_h, cnt, budget = hs.cnt_h, hs.cnt, hs.budget
        if moved_clusters:
            cnt_h = cnt_h.copy()
            for c in moved_clusters:
                occ = np.flatnonzero(flat[c * cap:(c + 1) * cap] != _SENT)
                cnt_h[c] = 0 if occ.size == 0 else int(occ[-1]) // tr + 1
            cnt = jnp.asarray(cnt_h)
            budget = _probe_budget(cnt_h, spec_h.nprobe, hs.tpc)
        return HierState(centroid_src=hs.centroid_src,
                         coarse_prepared=hs.coarse_prepared,
                         leaves=leaves, row_ids=rid, assign=assign,
                         slot_of=slot_of, row_ids_h=row_h, tpc=hs.tpc,
                         cnt=cnt, cnt_h=cnt_h, budget=budget)

    return prepare, chunk_fn, row_update


# ---------------------------------------------------------------------------
# The plan and its cached factory
# ---------------------------------------------------------------------------


@dataclass
class HierarchicalPlan(CompositePlan):
    """Two-stage coarse→fine search plan (see the module docstring).

    ``stages[0]`` is the coarse centroid :class:`~.plans.SearchPlan`.
    The public surface matches :class:`~.plans.SearchPlan` — same
    ``execute`` / ``dispatch`` / ``finalize`` / ``update_rows``
    signatures, same ``(values, indices)`` results — so the serving and
    hardening layers treat it as just another plan.
    """

    family: str = field(default="hierarchical", repr=False)

    @property
    def coarse(self) -> SearchPlan:
        return self.stages[0]

    def _stored_sources(self, inputs) -> Tuple:
        return (inputs[self.spec.pattern_arg],)

    def finalize(self, pending):
        """SearchPlan-shaped finalize with the hierarchical shard merge
        (composite-key lexsort instead of the shard-order value sort)."""
        with trace_span("plan.finalize"):
            return self._finalize(pending)

    def _finalize(self, pending):
        spec = self.spec
        xp = np if self.shards > 1 else jnp
        vs, is_ = [], []
        for v, i, valid in pending.chunks:
            if self.shards > 1:
                v, i = _merge_hier_shards(v, i, k=spec.k,
                                          largest=spec.largest)
            vs.append(v[:valid])
            is_.append(i[:valid])
        if not vs:      # zero queries: well-shaped empty result
            vs = [xp.zeros((0, spec.k), xp.float32)]
            is_ = [xp.zeros((0, spec.k), xp.int32)]
        v = vs[0] if len(vs) == 1 else xp.concatenate(vs, axis=0)
        i = is_[0] if len(is_) == 1 else xp.concatenate(is_, axis=0)
        m, lead, k = pending.m, pending.lead, spec.k
        if m * k == _size(spec.out_v_shape):
            v = v.reshape(spec.out_v_shape)
            i = i.reshape(spec.out_i_shape)
        else:
            v = v.reshape(lead + (k,))
            i = i.reshape(lead + (k,))
        return (v, i)

    def update_rows(self, gallery, indices, new_rows, care=None, *,
                    donate: bool = False):
        """Row-granular gallery mutation with cluster reassignment.

        Same contract as :meth:`~.plans.SearchPlan.update_rows`
        (returns the mutated gallery; incremental memo rewrite when the
        old layout is memoised; ``donate`` reuses buffers), plus the
        hierarchical semantics documented on the module: each touched
        row is re-assigned to its nearest *stored* centroid, moving
        between cluster tile groups when needed — bit-identical to a
        full re-layout with the same centroids.
        """
        if care is not None:
            raise ValueError("hierarchical plans have no care operand")
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        self._validate_update(idx, new_rows)
        upd = self._mutate_stored((gallery,), (new_rows,), idx, donate)
        return upd[0]


def _default_clusters(fine: SimilaritySpec) -> int:
    """``~sqrt(n)`` centroids (the classic IVF balance point), never
    more than the number of row tiles (a cluster below one tile of rows
    wastes probe steps) and never more than n."""
    est = max(2, int(round(math.sqrt(fine.n))))
    est = min(est, max(1, fine.n // fine.tile_rows))
    return max(1, min(est, fine.n))


def _coarse_spec(spec_h: HierarchicalSpec) -> SimilaritySpec:
    """The coarse stage's spec: top-``nprobe`` centroids under the fine
    metric *and polarity* (a largest=True fine search wants the
    farthest clusters), same column geometry as the fine spec."""
    fine = spec_h.fine
    c = spec_h.clusters
    tr = min(fine.tile_rows, c)
    return SimilaritySpec(
        metric=fine.metric, k=spec_h.nprobe, largest=fine.largest,
        tile_rows=tr, dims_per_tile=fine.dims_per_tile,
        grid_rows=-(-c // tr), grid_cols=fine.grid_cols,
        m=fine.m, n=c, dim=fine.dim, query_arg=0, pattern_arg=1,
        out_v_shape=(fine.m, spec_h.nprobe),
        out_i_shape=(fine.m, spec_h.nprobe))


def get_hierarchical_plan(program, *, clusters: Optional[int] = None,
                          nprobe: Optional[int] = None,
                          backend: str = "jnp",
                          batch: Optional[int] = None,
                          shards: Optional[int] = None,
                          pack: Optional[bool] = None,
                          kmeans_iters: int = 8,
                          seed: int = 0) -> Optional[HierarchicalPlan]:
    """Hierarchical plan for a similarity program, from the shared cache.

    ``program`` is a partitioned similarity :class:`~..ir.Module`, its
    :class:`~.spec.SimilaritySpec`, or an existing
    :class:`HierarchicalSpec` (whose clustering fields serve as the
    defaults).  Returns ``None`` for modules that are not pure
    similarity programs, mirroring ``get_plan``.

    ``clusters`` defaults to ``~sqrt(n)`` (capped at the row-tile
    count); ``nprobe`` defaults to ``REPRO_HIER_NPROBE`` when set, else
    ``clusters // 8``.  Both clamp into valid range (``nprobe <=
    clusters <= n``).  The coarse centroid plan is itself a cached
    :class:`~.plans.SearchPlan`; the hierarchical plan is one entry in
    the same process-wide cache, keyed by its frozen
    :class:`~.composite.HierarchicalSpec` (clustering parameters
    included — different ``clusters``/``nprobe``/``seed`` are different
    result contracts, so they must not share an executable).

    Restrictions: jnp backend only (the probing stage is a gather-heavy
    scan with no fused kernel yet) and no ternary programs.
    """
    if isinstance(program, HierarchicalSpec):
        fine = program.fine
        clusters = program.clusters if clusters is None else clusters
        nprobe = program.nprobe if nprobe is None else nprobe
        kmeans_iters = program.kmeans_iters
        seed = program.seed
    elif isinstance(program, SimilaritySpec):
        fine = program
    else:
        try:
            fine = extract_plan_spec(program)
        except Exception:
            fine = None
        if fine is None:
            return None
    if backend != "jnp":
        raise ValueError(
            f"hierarchical plans require the 'jnp' backend, got {backend!r}")
    if fine.care_arg is not None:
        raise ValueError("hierarchical search does not support ternary "
                         "(care-masked) programs")
    if clusters is None:
        clusters = _default_clusters(fine)
    clusters = max(1, min(int(clusters), fine.n))
    if nprobe is None:
        nprobe = env_int("REPRO_HIER_NPROBE", 0, min_value=0) or \
            max(1, clusters // 8)
    nprobe = max(1, min(int(nprobe), clusters))
    spec_h = HierarchicalSpec(fine=fine, clusters=clusters, nprobe=nprobe,
                              kmeans_iters=int(kmeans_iters), seed=int(seed))
    packed = _resolve_pack(fine, pack)
    s = _normalize_shards(shards)
    b = batch or _pick_batch(fine.m)
    key = (spec_h, backend, b, s, packed)

    def build():
        coarse = get_plan(module_for_spec(_coarse_spec(spec_h)),
                          backend="jnp", batch=b, pack=packed)
        prepare, chunk_fn, row_update = _build_hier_executable(
            spec_h, coarse, b, s, packed)
        return HierarchicalPlan(
            spec=spec_h, backend=backend, batch=b, shards=s, packed=packed,
            _prepare=prepare, _chunk_fn=chunk_fn, _row_update=row_update,
            stages=(coarse,))

    return _lookup_or_insert(key, build)
