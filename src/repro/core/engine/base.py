"""``PlanBase``: the machinery shared by every plan family.

A *plan* is a compiled, cached, reusable executable for one program
shape.  Whatever the family — top-k search, boolean range match, or a
composite built from other plans — the lifecycle is identical:

``prepare`` (encode/pack/lay out the stored operands, memoised per
source array) → ``dispatch`` (micro-batched async chunk execution) →
``finalize`` (shard merge / ragged slicing / output shaping) →
``update_rows`` (row-granular incremental re-layout).

:class:`PlanBase` owns that lifecycle: the dataclass fields (spec,
backend, batch, shards, packing, telemetry counters, the pattern-memo
LRU and its locks), the dispatch skeleton, the fault hooks
(``_normalize_faults`` + host-side corruption before the jitted
prepare), and the ``update_rows`` relay machinery
(``_seed_updated_memo``).  Leaf families (:class:`~.plans.SearchPlan`,
:class:`~.plans.RangePlan`) and composites
(:class:`~.composite.CompositePlan`) override only the points where
their result *structure* differs: how a chunk result is recorded, how
chunks finalize, and how stored operands are wired from the module
arguments.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...obs.trace import trace_span, tracer
from ..envcfg import env_flag, env_int
from .spec import _check_binary_cells

__all__ = ["PlanBase", "PendingSearch"]


def _pick_batch(m: int) -> int:
    """Micro-batch size: next power of two, clamped to the chunk cap.

    The clamp is applied *after* rounding up — a non-power-of-two cap
    (say 1000) must still bound the batch, not let the round-up jump
    over it to 1024.
    """
    cap = env_int("REPRO_ENGINE_MAX_CHUNK", 1024, min_value=1)
    b = 8
    while b < min(max(m, 1), cap):
        b *= 2
    return min(b, cap)


def _update_enabled() -> bool:
    """``REPRO_ENGINE_UPDATE`` kill switch for the incremental update
    path: ``off``/``0`` makes ``update_rows`` still apply the mutation
    but skip the memo rewrite — the next dispatch re-prepares in full
    (the pre-update behaviour, kept reachable for triage)."""
    return env_flag("REPRO_ENGINE_UPDATE", True)


def _normalize_faults(faults):
    """Validate/normalise a dispatch-time fault model.

    The engine duck-types the model (``is_null`` /
    ``corrupt_stored(srcs, spec)``, hashable) so ``repro.core`` never
    imports ``repro.faults``.  Null models normalise to ``None`` —
    that guarantees ``FaultModel(p_stuck=0)`` takes *exactly* the clean
    code path (same memo key, same prepared layout, bit-identical
    results).  The model is deliberately **not** part of the plan-cache
    key: faults corrupt the stored sources host-side before the jitted
    prepare, so the executables never retrace across fault epochs.
    """
    if faults is None:
        return None
    if not hasattr(faults, "is_null") or not hasattr(faults, "corrupt_stored"):
        raise TypeError(
            f"faults must be a repro.faults.FaultModel-like object, "
            f"got {type(faults).__name__}")
    return None if faults.is_null else faults


#: source-gallery mutation for update_rows.  The donating variant
#: reuses the old gallery's buffer (an in-place scatter — the 80 MB
#: copy of a large float gallery is otherwise the dominant update
#: cost); callers opt in only when nothing else references the array.
_scatter_rows = jax.jit(lambda g, i, r: g.at[i].set(r))
_scatter_rows_donated = jax.jit(lambda g, i, r: g.at[i].set(r),
                                donate_argnums=0)


def _as_2d(q: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    if q.ndim == 1:
        return q[None, :], ()
    if q.ndim == 2:
        return q, (q.shape[0],)
    lead = q.shape[:-1]
    return q.reshape((-1, q.shape[-1])), lead


def _size(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclass
class PendingSearch:
    """An async-dispatched search: chunk results not yet materialised.

    ``chunks`` holds per-micro-batch entries — ``(values, indices,
    valid_rows)`` for a search plan, ``(match, valid_rows)`` for a
    range plan — whose arrays are jax values still computing on-device.
    :meth:`PlanBase.finalize` turns a pending search into final host
    results.
    """

    plan: "PlanBase"
    m: int
    lead: Tuple[int, ...]
    chunks: list


def _src_ident(x) -> Tuple:
    """Memo identity of one stored-operand source array."""
    return (id(x), tuple(x.shape), str(x.dtype))


def _memo_insert(plan, srcs: Tuple[Any, ...], prepared,
                 faults=None) -> None:
    """Insert a prepared layout into the plan's pattern memo (LRU).

    The entry keeps strong references to the sources so their ids
    cannot be recycled while it lives — same contract as the miss path
    of :func:`_memoised_prepare`.  ``faults`` joins the key: a faulted
    layout must never shadow the clean one (or another model's).
    """
    with plan._pattern_lock:
        plan._pattern_cache[
            tuple(_src_ident(s) for s in srcs) + (faults,)] = \
            (srcs, prepared)
        slots = plan._pattern_cache_slots()
        while len(plan._pattern_cache) > slots:
            plan._pattern_cache.popitem(last=False)
            plan.pattern_evictions += 1


def _memoised_prepare(plan, srcs: Tuple[Any, ...], run: Callable[[], Any],
                      check: Callable[[], None], faults=None):
    """Per-plan pattern-prep memoisation shared by every plan family.

    ``srcs`` are the stored-operand sources the prepared layout derives
    from — ``(gallery,)``, ``(gallery, care)`` or ``(lo, hi)``; all must
    be immutable ``jax.Array`` values to be memoised (a numpy array can
    be mutated in place under an unchanged id/shape/dtype).  Mutable
    inputs re-prepare on every call and still count as telemetry misses
    — a numpy-gallery workload reading hits=0/misses=0 would look fully
    cached while re-packing the gallery on every search.  The cache
    entry keeps strong references to the sources so their ids cannot be
    recycled while it lives.  ``check`` runs only when actually
    preparing (memo hits skip it).

    ``faults`` (a normalised fault model or ``None``) is part of the
    memo key — the model is frozen/hashable, so repeated dispatches
    with the same model hit the same corrupted layout while the clean
    entry (``None``) stays untouched.
    """
    if not all(isinstance(s, jax.Array) for s in srcs):
        with plan._pattern_lock:
            plan.pattern_misses += 1
        check()
        return run()
    key = tuple(_src_ident(s) for s in srcs) + (faults,)
    with plan._pattern_lock:
        hit = plan._pattern_cache.get(key)
        if hit is not None:
            plan.pattern_hits += 1
            plan._pattern_cache.move_to_end(key)
            return hit[-1]
    with trace_span("plan.prepare",
                    args=None if not tracer.enabled else
                    {"plan": type(plan).__name__, "n": plan.spec.n}):
        check()
        prepared = run()
    with plan._pattern_lock:
        plan.pattern_misses += 1
    _memo_insert(plan, srcs, prepared, faults)
    return prepared


@dataclass
class PlanBase:
    """Shared base of every compiled plan (search / range / composite).

    Holds the tile-geometry spec, micro-batching, the pattern-prep memo,
    plan-cache participation (frozen-spec key, telemetry counters), the
    fault hooks and the ``update_rows`` relay machinery.  Subclasses
    define the family-specific structure: :meth:`_stored_sources`
    (which module arguments are stored operands), :meth:`_chunk_entry`
    (chunk result shape) and :meth:`finalize`.
    """

    spec: Any
    backend: str
    batch: int
    _prepare: Callable = field(repr=False)
    _chunk_fn: Callable = field(repr=False)
    shards: int = 1
    #: bit-packed execution (uint32 lanes, XOR+popcount physical search)
    packed: bool = False
    #: dense one-tile executable (small single-column-tile programs):
    #: dispatch may skip the micro-batch machinery entirely — the
    #: executables are shape-polymorphic in the query count
    tiny: bool = False
    #: backend-specific incremental row-update closure (see update_rows)
    _row_update: Optional[Callable] = field(default=None, repr=False)
    #: jnp-backend ``lax.scan`` unroll factor (tile steps per scan
    #: iteration); an autotuner search axis, so it joins the cache key
    unroll: int = 1
    executions: int = 0
    chunks_run: int = 0
    pattern_hits: int = 0
    pattern_misses: int = 0
    pattern_evictions: int = 0
    # pattern-counter values already folded into the process-wide
    # retained stats by plan-LRU retirement.  Retirement must NOT zero
    # the live counters — a server still holding an evicted plan keeps
    # incrementing them, and zeroing would make its telemetry (and a
    # re-inserted plan's contribution to plan_cache_stats()) jump
    # backwards or double-count.  Instead _retire_plan folds the delta
    # above these bases and advances them (idempotent against live
    # references); plan_cache_stats() counts live plans net of them.
    _retired_hits: int = field(default=0, repr=False)
    _retired_misses: int = field(default=0, repr=False)
    _retired_evictions: int = field(default=0, repr=False)
    #: update_rows telemetry: calls, total rows rewritten, and calls
    #: that could not take the incremental path (memo miss / kill
    #: switch / mutable sources) and fell back to full re-prepare
    row_updates: int = 0
    rows_updated: int = 0
    row_update_fallbacks: int = 0
    _pattern_cache: "OrderedDict[Tuple, Tuple[Any, ...]]" = \
        field(default_factory=OrderedDict, repr=False)
    # plans are shared process-wide (the plan cache hands the same object
    # to every caller), so the memo needs its own lock
    _pattern_lock: threading.Lock = field(default_factory=threading.Lock,
                                          repr=False)
    # executions / chunks_run are bumped from every serving worker thread
    # driving the shared plan; unguarded += would drop counts
    _stats_lock: threading.Lock = field(default_factory=threading.Lock,
                                        repr=False)

    #: plan-family tag ("search" / "range" / "hierarchical"), for
    #: telemetry and serving snapshots
    family: str = field(default="search", repr=False)

    @staticmethod
    def _pattern_cache_slots() -> int:
        """LRU bound on memoised prepared galleries (per plan).

        Small on purpose: a prepared gallery is the dominant resident
        cost of a plan (float galleries especially), and a serving
        process typically cycles between a handful of live galleries.
        ``REPRO_ENGINE_PATTERN_SLOTS`` tunes it; evictions are counted
        and surfaced via :func:`plan_cache_stats`.
        """
        return env_int("REPRO_ENGINE_PATTERN_SLOTS", 4, min_value=1)

    # -- family-specific wiring (leaf overrides) ---------------------------

    def _stored_sources(self, inputs) -> Tuple[Any, ...]:
        """The stored-operand sources among the module arguments."""
        raise NotImplementedError

    def _chunk_entry(self, out, valid: int):
        """Record one micro-batch's executable output in ``chunks``."""
        raise NotImplementedError

    def finalize(self, pending: "PendingSearch"):
        raise NotImplementedError

    # -- prepare -----------------------------------------------------------

    def _prepared_patterns(self, *srcs, faults=None):
        """Encode + lay out the stored operands, memoised per input array.

        Only *immutable* inputs (``jax.Array``) are memoised — a numpy
        gallery can be mutated in place under an unchanged
        id/shape/dtype, which would silently serve stale prepared
        patterns.  Mutable inputs are re-prepared on every call (the
        pre-engine behaviour); callers wanting the memo pass the
        gallery as a jax array.  Multi-operand plans (ternary care
        masks, interval lo/hi pairs) key on the full source tuple.

        ``faults`` (already normalised) corrupts the stored sources
        host-side *before* the jitted prepare — the executable itself
        is fault-agnostic, so injecting faults never retraces.
        """
        def check():
            # guarded before (not inside) the jitted prepare, and only
            # when actually preparing — memo hits skip it: packing
            # collapses non-binary alphabets silently, see the guard
            if self.packed and self.spec.metric == "hamming":
                _check_binary_cells(srcs[0], "patterns")

        def run():
            if faults is not None:
                use = faults.corrupt_stored(
                    tuple(np.asarray(s) for s in srcs), self.spec)
                return self._prepare(*(jnp.asarray(u) for u in use))
            return self._prepare(*(s if isinstance(s, jax.Array)
                                   else jnp.asarray(s) for s in srcs))

        return _memoised_prepare(self, tuple(srcs), run, check, faults)

    def warm(self, *stored, faults=None) -> Tuple[Any, ...]:
        """Prime the pattern memo for ``stored`` without dispatching.

        Converts the stored operands to jax arrays (numpy inputs would
        bypass the memo), runs the encode/pack/layout prepare once, and
        returns the converted source tuple — callers that keep serving
        from exactly these array objects hit the memo on every later
        dispatch.  This is the serving cold-start hook: a gateway warms
        a tenant's plan at registration, and every replica constructed
        around the *same* returned arrays shares one prepared layout.
        """
        faults = _normalize_faults(faults)
        srcs = tuple(s if isinstance(s, jax.Array) else jnp.asarray(s)
                     for s in stored)
        self._prepared_patterns(*srcs, faults=faults)
        return srcs

    def counters(self) -> dict:
        """Consistent copy of the plan's telemetry counters.

        Execution counters are read under the stats lock, pattern-memo
        counters under the memo lock — no counter is observed
        mid-increment (``+=`` from another serving thread).
        """
        with self._stats_lock:
            out = {"executions": self.executions,
                   "chunks_run": self.chunks_run,
                   "row_updates": self.row_updates,
                   "rows_updated": self.rows_updated,
                   "row_update_fallbacks": self.row_update_fallbacks}
        with self._pattern_lock:
            out.update(pattern_hits=self.pattern_hits,
                       pattern_misses=self.pattern_misses,
                       pattern_evictions=self.pattern_evictions)
        return out

    # -- dispatch / execute ------------------------------------------------

    def dispatch(self, *inputs, faults=None) -> "PendingSearch":
        """Enqueue the plan's chunks without waiting for device results.

        Returns a :class:`PendingSearch` whose chunk arrays are
        async-dispatched jax values; pass it to :meth:`finalize` to
        materialise the results.  The split lets a serving loop
        dispatch the next micro-batch while the device still runs the
        previous one.

        Thread-safe: the serving layer drives one shared plan from many
        worker threads.  The jitted executables are pure, the pattern
        memo has its own lock, and the stats counters are guarded here.

        ``faults`` injects a device-fault model (see ``repro.faults``):
        the stored operands are corrupted host-side before the prepare,
        the queries and executables stay clean.  A null model is
        normalised away, so ``faults=FaultModel(p_stuck=0)`` is
        bit-identical to ``faults=None``.
        """
        faults = _normalize_faults(faults)
        with self._stats_lock:
            self.executions += 1
        spec = self.spec
        q_src = inputs[spec.query_arg]
        srcs = self._stored_sources(inputs)
        q2, lead = _as_2d(jnp.asarray(q_src))
        m = q2.shape[0]
        # host-resident queries are validated for free (they are about to
        # be transferred anyway; the serving layer always passes numpy
        # rows).  Device-resident jax queries skip the per-dispatch check
        # — np.asarray on them would block mid-dispatch and defeat the
        # async dispatch/finalize pipelining; the memo-miss gallery guard
        # still catches the realistic failure (one encoding pipeline
        # feeding both operands a non-binary alphabet).
        if self.packed and spec.metric == "hamming" and \
                not isinstance(q_src, jax.Array):
            _check_binary_cells(q_src, "queries")
        with trace_span("plan.dispatch",
                        args=None if not tracer.enabled else
                        {"plan": type(self).__name__,
                         "family": self.family, "m": m,
                         "batch": self.batch}):
            pp = self._prepared_patterns(*srcs, faults=faults)

            b = self.batch
            chunks = []
            if self.tiny and m <= b:
                # tiny-plan fast path: the whole gallery is one dense
                # tile and the query block fits one micro-batch, so the
                # chunk loop, tail padding and result slicing are pure
                # overhead next to the (small) search itself.  The
                # dense executable is shape-polymorphic — it traces at
                # the caller's m, which small-program workloads (forest
                # inference, interactive probes) hold constant.
                out = self._chunk_fn(q2, pp)
                with self._stats_lock:
                    self.chunks_run += 1
                return PendingSearch(plan=self, m=m, lead=lead,
                                     chunks=[self._chunk_entry(out, m)])
            for s in range(0, m, b):
                chunk = q2[s:s + b]
                valid = chunk.shape[0]
                if valid < b:
                    chunk = jnp.pad(chunk, ((0, b - valid), (0, 0)))
                out = self._chunk_fn(chunk, pp)
                with self._stats_lock:
                    self.chunks_run += 1
                chunks.append(self._chunk_entry(out, valid))
            return PendingSearch(plan=self, m=m, lead=lead, chunks=chunks)

    def execute(self, *inputs, faults=None):
        """Run the plan; accepts exactly the compiled module's arguments.

        Always returns jax arrays, regardless of shard count (the
        sharded finalize merges on host; converting back keeps the
        public output type shard-invariant).  Serving loops that want
        the host arrays directly use dispatch/finalize themselves.
        ``faults`` is forwarded to :meth:`dispatch`.
        """
        out = self.finalize(self.dispatch(*inputs, faults=faults))
        if self.shards <= 1:
            return out
        if isinstance(out, tuple):
            return tuple(jnp.asarray(o) for o in out)
        return jnp.asarray(out)

    # -- gallery mutation (update_rows relay machinery) --------------------

    def _validate_update(self, idx: np.ndarray, *new_rows) -> None:
        spec = self.spec
        if idx.ndim != 1:
            raise ValueError(f"indices must be 1-D, got shape {idx.shape}")
        if idx.size == 0:
            return
        if idx.min() < 0 or idx.max() >= spec.n:
            raise ValueError(
                f"row indices out of range for an n={spec.n} gallery")
        if np.unique(idx).size != idx.size:
            # jax scatter with duplicate indices picks an unspecified
            # winner; reject instead of silently choosing one
            raise ValueError("duplicate row indices in update_rows")
        for nr in new_rows:
            if tuple(np.shape(nr)) != (idx.size, spec.dim):
                raise ValueError(
                    f"new rows shape {np.shape(nr)} != "
                    f"({idx.size}, {spec.dim})")

    def _seed_updated_memo(self, old_srcs: Tuple[Any, ...],
                           new_srcs: Tuple[Any, ...], idx: np.ndarray,
                           donate: bool = False) -> None:
        """Derive the mutated sources' prepared layout from the old one.

        Incremental only when the old layout is memoised (immutable
        jax-array sources that have been prepared and not evicted) and
        the update path is enabled; otherwise a counted fallback — the
        next dispatch re-prepares the new sources in full, which is
        always correct, just not incremental.

        ``donate`` (the caller just invalidated the old gallery):
        the stale memo entry is popped and its prepared leaves' buffers
        are reused in place for the fresh-tile scatter — no full-leaf
        copy per update.
        """
        with self._stats_lock:
            self.row_updates += 1
            self.rows_updated += int(idx.size)
        if self._row_update is None or not _update_enabled() or \
                not all(isinstance(s, jax.Array) for s in old_srcs):
            with self._stats_lock:
                self.row_update_fallbacks += 1
            return
        # only the clean (faults=None) entry is rewritten incrementally;
        # faulted layouts re-prepare in full on the next faulted
        # dispatch — fault masks are position-keyed, so a row moving
        # through update_rows must re-draw its cell faults anyway
        key = tuple(_src_ident(s) for s in old_srcs) + (None,)
        with self._pattern_lock:
            if donate:       # the old layout must not outlive its buffers
                hit = self._pattern_cache.pop(key, None)
            else:
                hit = self._pattern_cache.get(key)
        if hit is None:
            with self._stats_lock:
                self.row_update_fallbacks += 1
            return
        prepared = self._row_update(hit[-1], new_srcs, idx, donate)
        _memo_insert(self, new_srcs, prepared)

    def _mutate_stored(self, olds: Tuple[Any, ...], news: Tuple[Any, ...],
                       idx: np.ndarray, donate: bool) -> Tuple[Any, ...]:
        """Scatter ``news`` row blocks into the leading stored operands
        and seed the mutated sources' memo entry.  Operands beyond
        ``len(news)`` (a ternary plan's immutable care mask) pass
        through unchanged but stay part of the memo key."""
        gj = tuple(o if isinstance(o, jax.Array) else jnp.asarray(o)
                   for o in olds)
        if idx.size == 0:
            return gj
        if self.packed and self.spec.metric == "hamming":
            _check_binary_cells(news[0], "updated rows")
        with trace_span("plan.update_rows",
                        args=None if not tracer.enabled else
                        {"plan": type(self).__name__,
                         "rows": int(idx.size)}):
            j = jnp.asarray(idx)
            scatter = _scatter_rows_donated if donate else _scatter_rows
            upd = tuple(scatter(g, j, jnp.asarray(nr).astype(g.dtype))
                        for g, nr in zip(gj, news)) + gj[len(news):]
            self._seed_updated_memo(gj, upd, idx, donate)
            return upd
