"""The plan-graph layer: plans composed from other plans.

The leaf families execute one program shape each.  A *composite* plan
wires several plans into a graph — one stage's outputs become the next
stage's inputs — while remaining a first-class plan itself: same frozen
spec key in the shared plan cache, same micro-batched dispatch /
finalize lifecycle, same pattern-memo, fault and ``update_rows``
machinery inherited from :class:`~.base.PlanBase`.

Two things live here:

* :class:`HierarchicalSpec` — the frozen spec of a two-stage
  coarse→fine search (the CAM analogue of an IVF index): a coarse
  :class:`~.plans.SearchPlan` over cluster centroids selects the
  ``nprobe`` most promising clusters per query, and a fine probing
  stage searches only those clusters' row tiles.  The spec *wraps* the
  fine :class:`~.spec.SimilaritySpec` — its flat equivalent — so cache
  keys can never collide with a flat similarity (different type) and
  :func:`~.spec.module_for_spec` can synthesise the exact search the
  composite approximates (``flat_spec``).

* :class:`CompositePlan` — the dataclass base for plans built from
  other plans: a ``stages`` tuple of member plans plus aggregated
  telemetry.  The concrete two-stage search is
  :class:`~.hier.HierarchicalPlan`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .base import PlanBase
from .spec import SimilaritySpec

__all__ = ["CompositePlan", "HierarchicalSpec"]


@dataclass(frozen=True)
class HierarchicalSpec:
    """Structural summary of a two-stage hierarchical similarity search.

    ``fine`` is the flat :class:`~.spec.SimilaritySpec` this search
    approximates — same metric, k, polarity, tile geometry and operand
    wiring; the composite merely restricts *which* row tiles are
    searched per query.  The clustering parameters are part of the
    frozen spec (and therefore of the plan-cache key): two hierarchical
    plans with different ``clusters`` / ``nprobe`` / ``seed`` are
    different executables with different result contracts.

    With ``nprobe == clusters`` every tile is probed and the result is
    bit-identical to the flat plan's (the probing stage selects by the
    same (physical value, global row id) composite key the flat
    tournament resolves ties by); smaller ``nprobe`` trades recall for
    probing ~``nprobe / clusters`` of the gallery.
    """

    fine: SimilaritySpec
    clusters: int
    nprobe: int
    #: Lloyd iterations of the seeded k-means that places the centroids
    kmeans_iters: int = 8
    seed: int = 0

    # -- delegation: a HierarchicalSpec answers every structural question
    # its flat equivalent answers, so PlanBase machinery (dispatch
    # wiring, update validation, fault models) works unchanged ---------

    @property
    def flat_spec(self) -> SimilaritySpec:
        """The exact flat search this composite approximates (read by
        ``module_for_spec`` and the serving fallback chain)."""
        return self.fine

    @property
    def metric(self) -> str:
        return self.fine.metric

    @property
    def k(self) -> int:
        return self.fine.k

    @property
    def largest(self) -> bool:
        return self.fine.largest

    @property
    def tile_rows(self) -> int:
        return self.fine.tile_rows

    @property
    def dims_per_tile(self) -> int:
        return self.fine.dims_per_tile

    @property
    def grid_rows(self) -> int:
        return self.fine.grid_rows

    @property
    def grid_cols(self) -> int:
        return self.fine.grid_cols

    @property
    def m(self) -> int:
        return self.fine.m

    @property
    def n(self) -> int:
        return self.fine.n

    @property
    def dim(self) -> int:
        return self.fine.dim

    @property
    def query_arg(self) -> int:
        return self.fine.query_arg

    @property
    def pattern_arg(self) -> int:
        return self.fine.pattern_arg

    @property
    def care_arg(self) -> Optional[int]:
        return self.fine.care_arg

    @property
    def in_dtypes(self) -> Tuple[str, ...]:
        return self.fine.in_dtypes

    @property
    def out_v_shape(self) -> Tuple[int, ...]:
        return self.fine.out_v_shape

    @property
    def out_i_shape(self) -> Tuple[int, ...]:
        return self.fine.out_i_shape


@dataclass
class CompositePlan(PlanBase):
    """Base of plans whose executable is built from other plans.

    ``stages`` holds the member plans in execution order (for the
    hierarchical family: the coarse centroid :class:`~.plans.SearchPlan`).
    Member plans are ordinary cached plans — they keep their own
    telemetry, pattern memos and jitted executables; the composite's
    ``_chunk_fn`` stitches their chunk executables together so one
    dispatch drives the whole graph without a host round-trip per
    stage.

    The composite is itself one entry in the shared plan cache (its
    frozen spec is the key), *not* a wrapper the caller must assemble:
    ``get_hierarchical_plan`` returns the same object for the same
    (spec, backend, batch, shards, packed) tuple, exactly like
    ``get_plan``.
    """

    stages: Tuple[PlanBase, ...] = ()
    family: str = field(default="composite", repr=False)

    def _chunk_entry(self, out, valid: int):
        # search-shaped results by default: (values, indices, valid)
        v, i = out
        return (v, i, valid)

    def graph_stats(self) -> Dict[str, object]:
        """Aggregated telemetry: the composite's own counters plus each
        member stage's, keyed ``stage<idx>:<family>``.  Stage counters
        reflect the member plan's *own* dispatches (a stage driven
        through the composite's fused ``_chunk_fn`` executes without
        bumping the member's counters — the work is accounted to the
        composite)."""
        with self._stats_lock:
            out: Dict[str, object] = {
                "family": self.family,
                "executions": self.executions,
                "chunks_run": self.chunks_run,
                "row_updates": self.row_updates,
            }
        for idx, st in enumerate(self.stages):
            with st._stats_lock:
                out[f"stage{idx}:{st.family}"] = {
                    "executions": st.executions,
                    "chunks_run": st.chunks_run,
                }
        return out
