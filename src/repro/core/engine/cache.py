"""The process-wide plan cache and its public entry point, ``get_plan``.

One cache for every plan family: keys are ``(spec, backend, batch,
shards, packed, unroll)`` where the spec is a frozen dataclass —
:class:`~.spec.SimilaritySpec`, :class:`~.spec.RangeSpec` or
:class:`~.composite.HierarchicalSpec` — so keys from different families
can never collide.  Recompiling the same program, or a different
program with identical structure (exactly what a DSE sweep over
optimization targets produces), returns the *same* plan object and
reuses its jitted executables instead of re-tracing.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import jax

from ...obs.trace import trace_span, tracer
from ..envcfg import env_int
from ..ir import Module
from .base import PlanBase, _pick_batch
from .executables import (_build_pallas_executable,
                          _build_range_pallas_executable,
                          _build_range_scan_executable,
                          _build_range_sharded_executable,
                          _build_scan_executable, _build_sharded_executable,
                          _build_tiny_executable,
                          _build_tiny_range_executable)
from .plans import RangePlan, SearchPlan
from .spec import (RangeSpec, _resolve_pack, extract_plan_spec,
                   extract_range_spec)

_PLAN_CACHE: "OrderedDict[Tuple, PlanBase]" = OrderedDict()
#: LRU bound — a DSE sweep over many distinct geometries must not pin
#: every plan (and its memoised galleries) forever
_MAX_PLANS = 64
_CACHE_LOCK = threading.Lock()
#: pattern_* entries retain the pattern-memo counters of plans evicted
#: from the LRU, keeping plan_cache_stats() monotonic across evictions
_STATS = {"hits": 0, "misses": 0,
          "pattern_hits": 0, "pattern_misses": 0, "pattern_evictions": 0}


def _retire_plan(plan: PlanBase) -> None:
    """Fold an evicted plan's pattern counters into the retained stats.

    A server (or any live reference) may still be driving the evicted
    plan, so the live counters are never zeroed — that would make the
    holder's ``counters()`` telemetry jump backwards mid-serve.
    Instead the delta above the plan's ``_retired_*`` bases is folded
    into ``_STATS`` and the bases advance, which makes retirement
    idempotent: retiring twice (evict, re-insert, evict again) folds
    each increment exactly once, and :func:`plan_cache_stats` counts a
    live plan net of its bases so a re-inserted retired plan is never
    double-counted.

    Caller holds ``_CACHE_LOCK``; lock order ``_CACHE_LOCK`` ->
    ``_pattern_lock`` is safe (no path acquires them in reverse).
    """
    with plan._pattern_lock:
        _STATS["pattern_hits"] += plan.pattern_hits - plan._retired_hits
        _STATS["pattern_misses"] += plan.pattern_misses - plan._retired_misses
        _STATS["pattern_evictions"] += \
            plan.pattern_evictions - plan._retired_evictions
        plan._retired_hits = plan.pattern_hits
        plan._retired_misses = plan.pattern_misses
        plan._retired_evictions = plan.pattern_evictions


def _normalize_shards(shards: Optional[int]) -> int:
    """Effective shard count: ``None``/<=1 means unsharded; requests are
    clamped to the host's device count (a plan asking for 8-way sharding
    on a 1-device host degrades to the single-device executable)."""
    if shards is None or shards <= 1:
        return 1
    return max(1, min(int(shards), jax.device_count()))


def _cache_lookup(key: Tuple) -> Optional[PlanBase]:
    with _CACHE_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _STATS["hits"] += 1
            _PLAN_CACHE.move_to_end(key)
            return plan
        _STATS["misses"] += 1
    return None


def _cache_insert(key: Tuple, plan: PlanBase) -> PlanBase:
    with _CACHE_LOCK:
        # lost-race double insert is harmless but keep one canonical plan
        plan = _PLAN_CACHE.setdefault(key, plan)
        _PLAN_CACHE.move_to_end(key)
        while len(_PLAN_CACHE) > _MAX_PLANS:
            _, evicted = _PLAN_CACHE.popitem(last=False)
            _retire_plan(evicted)
    return plan


def _lookup_or_insert(key: Tuple, build: Callable[[], PlanBase]) -> PlanBase:
    """Shared cache participation for plan factories outside this module
    (the composite/hierarchical family): counted lookup, build on miss,
    canonical insert with the same LRU/race semantics as ``get_plan``."""
    plan = _cache_lookup(key)
    if plan is not None:
        return plan
    with trace_span("plan.compile",
                    args=None if not tracer.enabled else
                    {"key": repr(key[1:])}):
        built = build()
    return _cache_insert(key, built)


def _tiny_plan(spec, backend: str, shards: int) -> bool:
    """Small-program fast path eligibility (ROADMAP item 5).

    A plan is *tiny* when its whole gallery collapses into one dense
    tile with identical semantics: a single column tile (full-width
    distances — dense and tiled arithmetic coincide), the jnp backend,
    no sharding, and a physical cell count small enough that per-tile
    ``lax.scan`` stepping would dominate the arithmetic.  The threshold
    is ``REPRO_ENGINE_TINY_CELLS`` (physical rows x logical dims;
    ``0`` disables the fast path).
    """
    if backend != "jnp" or shards != 1 or spec.grid_cols != 1:
        return False
    cells = spec.grid_rows * spec.tile_rows * spec.dim
    return cells <= env_int("REPRO_ENGINE_TINY_CELLS", 32768, min_value=0)


def get_plan(module: Module, *, backend: str = "jnp",
             batch: Optional[int] = None,
             shards: Optional[int] = None,
             pack: Optional[bool] = None,
             unroll: Optional[int] = None) -> Optional[PlanBase]:
    """Plan for a partitioned module, from the cache when possible.

    ``shards > 1`` selects the multi-device executable: gallery rows
    sharded over a ``("data",)`` mesh, cross-device ``merge_topk``
    tournament (see ``_build_sharded_executable``).  The effective shard
    count is part of the plan-cache key.

    ``pack`` selects bit-packed execution (uint32 lanes, XOR+popcount):
    ``None`` auto-packs binary/bipolar metrics (hamming / dot / cos) —
    bit-identical results at 1/32nd the gallery footprint — ``False``
    forces the float path, ``True`` on an analog metric raises.  The
    effective packing joins the plan-cache key: a packed and an unpacked
    plan for the same geometry are different executables and must never
    collide (their prepared operands have different dtypes).

    ``unroll`` sets the jnp ``lax.scan`` unroll factor (tile steps
    fused per scan iteration) — a pure scheduling knob with identical
    arithmetic at any value, exposed as an autotuner search axis.
    ``None`` means 1; the pallas backend has no scan to unroll and
    always normalises to 1.  The effective factor joins the cache key.

    When a persistent plan store is configured (``REPRO_PLAN_STORE``),
    a freshly built single-device jnp plan additionally consults it for
    an AOT-serialized executable pair matching this exact key — adopted
    executables skip XLA compilation entirely (see ``repro.tune``).

    Returns ``None`` when the module is not a pure similarity program
    (callers then fall back to the IR interpreter).
    """
    try:
        spec = extract_plan_spec(module)
        if spec is None:
            spec = extract_range_spec(module)
    except Exception:       # malformed/exotic IR: the interpreter handles it
        spec = None
    if spec is None:
        return None
    if backend not in ("jnp", "pallas"):
        return None
    if shards is not None and shards > 1 and backend != "jnp":
        # checked on the *requested* count, before device clamping, so
        # the refusal does not depend on how many devices this host has
        raise ValueError(
            f"sharded plans require the 'jnp' backend, got {backend!r}")
    is_range = isinstance(spec, RangeSpec)
    packed = _resolve_pack(spec, pack)
    if is_range and backend == "pallas" and packed:
        # the fused range kernels take float cells; the packed popcount
        # range path lives in the jnp executable
        if pack:
            raise ValueError(
                "packed range search requires the 'jnp' backend")
        packed = False
    if getattr(spec, "care_arg", None) is not None and not packed \
            and backend == "pallas":
        raise ValueError(
            "ternary (care-masked) search on the pallas backend requires "
            "packed execution; pass pack=True (and unset "
            "REPRO_ENGINE_PACK=off if the kill switch disabled auto-pack)")
    s = _normalize_shards(shards)
    b = batch or _pick_batch(spec.m)
    u = 1 if unroll is None or backend == "pallas" else max(1, int(unroll))
    key = (spec, backend, b, s, packed, u)
    plan = _cache_lookup(key)
    if plan is not None:
        return plan
    tiny = _tiny_plan(spec, backend, s)
    with trace_span("plan.compile",
                    args=None if not tracer.enabled else
                    {"family": "range" if is_range else "search",
                     "backend": backend, "batch": b, "shards": s,
                     "packed": packed, "unroll": u}):
        plan = _build_leaf_plan(spec, backend, b, s, packed, tiny,
                                is_range, u)
        _maybe_adopt_stored_exec(plan)
    return _cache_insert(key, plan)


def _maybe_adopt_stored_exec(plan: PlanBase) -> None:
    """Swap a freshly built plan's jitted executables for AOT-serialized
    ones from the persistent plan store, when one is configured and
    holds a matching entry.

    Only single-device jnp non-tiny plans are eligible (tiny plans are
    shape-polymorphic, sharded plans bake in a device topology, pallas
    kernels carry their own compilation path).  The engine never
    imports ``repro.tune`` at module scope — the store stays an
    optional layer above the engine.
    """
    if plan.backend != "jnp" or plan.shards != 1 or plan.tiny:
        return
    try:
        from ...tune.store import active_store
        store = active_store()
    except Exception:       # tune layer unavailable: engine stays standalone
        return
    if store is not None:
        store.adopt_executables(plan)


def _build_leaf_plan(spec, backend: str, b: int, s: int, packed: bool,
                     tiny: bool, is_range: bool, unroll: int = 1) -> PlanBase:
    if is_range:
        if s > 1:
            prepare, chunk_fn, row_update = _build_range_sharded_executable(
                spec, b, s, packed=packed, unroll=unroll)
        elif backend == "pallas":
            prepare, chunk_fn, row_update = _build_range_pallas_executable(
                spec, b)
        elif tiny:
            prepare, chunk_fn, row_update = _build_tiny_range_executable(
                spec, b, packed=packed, unroll=unroll)
        else:
            prepare, chunk_fn, row_update = _build_range_scan_executable(
                spec, b, packed=packed, unroll=unroll)
        plan = RangePlan(spec=spec, backend=backend, batch=b, shards=s,
                         packed=packed, tiny=tiny, unroll=unroll,
                         _prepare=prepare,
                         _chunk_fn=chunk_fn, _row_update=row_update)
    else:
        if s > 1:
            prepare, chunk_fn, row_update = _build_sharded_executable(
                spec, b, s, packed=packed, unroll=unroll)
        elif backend == "pallas":
            prepare, chunk_fn, row_update = _build_pallas_executable(
                spec, b, packed=packed)
        elif tiny:
            prepare, chunk_fn, row_update = _build_tiny_executable(
                spec, b, packed=packed, unroll=unroll)
        else:
            prepare, chunk_fn, row_update = _build_scan_executable(
                spec, b, packed=packed, unroll=unroll)
        plan = SearchPlan(spec=spec, backend=backend, batch=b, shards=s,
                          packed=packed, tiny=tiny, unroll=unroll,
                          _prepare=prepare,
                          _chunk_fn=chunk_fn, _row_update=row_update)
    return plan


def plan_cache_stats() -> Dict[str, int]:
    """Process-wide cache counters.

    Plan cache (hits / misses / live plans) plus the pattern-prep memo
    counters (each plan's memoised prepared-gallery LRU — see
    ``PlanBase._prepared_patterns``): ``pattern_hits`` /
    ``pattern_misses`` / ``pattern_evictions``, summed over the live
    plans plus the retained totals of plans the 64-slot LRU evicted —
    monotonic until :func:`clear_plan_cache` resets everything.
    """
    # the whole aggregation holds _CACHE_LOCK so a concurrent eviction
    # cannot move a plan's counters into _STATS between the snapshot and
    # the live sum (which would transiently undercount); the established
    # lock order _CACHE_LOCK -> _pattern_lock makes the nesting safe
    with _CACHE_LOCK:
        out = {"hits": _STATS["hits"], "misses": _STATS["misses"],
               "plans": len(_PLAN_CACHE)}
        ph = _STATS["pattern_hits"]
        pm = _STATS["pattern_misses"]
        pe = _STATS["pattern_evictions"]
        for p in _PLAN_CACHE.values():
            with p._pattern_lock:
                # net of the retired bases: a previously-evicted plan
                # that found its way back into the cache already has
                # its pre-retirement counts folded into _STATS above
                ph += p.pattern_hits - p._retired_hits
                pm += p.pattern_misses - p._retired_misses
                pe += p.pattern_evictions - p._retired_evictions
    out.update(pattern_hits=ph, pattern_misses=pm, pattern_evictions=pe)
    return out


def clear_plan_cache() -> None:
    with _CACHE_LOCK:
        _PLAN_CACHE.clear()
        for k in _STATS:
            _STATS[k] = 0
