"""Search-plan execution engine: compiled, cached execution of ``cim`` IR.

The functional executor (:mod:`repro.core.executor`) interprets the
partitioned ``cim`` IR op-by-op — fine for pinning semantics, but DSE
sweeps (Fig. 8, Table II) and serving workloads pay Python-loop and
retrace costs at every call.  This package compiles a partitioned
program **once** into a *plan* and caches it process-wide.

The package follows the paper's layering (a hierarchy of abstractions,
each transformation at the level where it fits best):

* :mod:`.spec` — frozen plan specs (:class:`SimilaritySpec`,
  :class:`RangeSpec`) and the structural IR analysis
  (:func:`extract_plan_spec` / :func:`extract_range_spec` /
  :func:`module_for_spec`).
* :mod:`.base` — :class:`PlanBase`: the lifecycle every plan family
  shares (micro-batched dispatch, pattern-prep memoisation, fault
  hooks, the ``update_rows`` relay machinery).
* :mod:`.executables` — the jitted backend triples (jnp reference-tiled
  scan, sharded ``shard_map``, fused Pallas kernels, dense tiny-plan
  fast path) and :func:`merge_shard_candidates`.
* :mod:`.plans` — the leaf families :class:`SearchPlan` (top-k) and
  :class:`RangePlan` (boolean match).
* :mod:`.cache` — the process-wide plan cache behind :func:`get_plan` /
  :func:`plan_cache_stats` / :func:`clear_plan_cache`.
* :mod:`.composite` — the plan-graph layer: :class:`CompositePlan`
  (plans built from other plans) and :class:`HierarchicalSpec`.
* :mod:`.hier` — :class:`HierarchicalPlan`: IVF-style two-stage search
  (coarse centroid ``SearchPlan`` -> fine probing of the selected
  cluster tiles), built via :func:`get_hierarchical_plan`.

Semantics, numerical contracts (bit-identical integer metrics, packed
popcount path, sharded tournament merges) and the gallery-mutation
story are documented on the submodules and in ``docs/engine.md``.
"""

from .base import (PendingSearch, PlanBase, _as_2d, _normalize_faults,
                   _pick_batch, _scatter_rows, _scatter_rows_donated,
                   _update_enabled)
from .cache import (_MAX_PLANS, clear_plan_cache, get_plan, plan_cache_stats)
from .composite import CompositePlan, HierarchicalSpec
from .executables import merge_shard_candidates
from .hier import HierarchicalPlan, get_hierarchical_plan
from .plans import RangePlan, SearchPlan
from .spec import (RangeSpec, SimilaritySpec, _bits, _check_binary_cells,
                   _encode, _metric_values, _resolve_pack, extract_plan_spec,
                   extract_range_spec, module_for_spec, spec_digest,
                   spec_fingerprint, workload_digest)

__all__ = [
    "SimilaritySpec", "RangeSpec", "HierarchicalSpec",
    "PlanBase", "SearchPlan", "RangePlan", "CompositePlan",
    "HierarchicalPlan", "PendingSearch",
    "extract_plan_spec", "extract_range_spec",
    "get_plan", "get_hierarchical_plan", "merge_shard_candidates",
    "module_for_spec", "plan_cache_stats", "clear_plan_cache",
    "spec_digest", "spec_fingerprint", "workload_digest",
]
