"""Backend executables: the jitted prepare / chunk / row-update triples.

Every builder returns ``(prepare, chunk_fn, row_update)``:

* ``prepare(*stored)`` encodes / packs / lays out the stored operands
  as per-subarray tile leaves (hoisted behind the plan's pattern memo);
* ``chunk_fn(q_chunk, prepared)`` executes one query micro-batch —
  top-k candidates for similarity plans, a boolean match block for
  range plans;
* ``row_update(prepared, new_srcs, idx, donate)`` re-lays only the row
  tiles touched by a gallery mutation (see ``PlanBase.update_rows``).

Three backends per family: the jnp reference-tiled scan, the sharded
``shard_map`` variant (collective-free per-device programs + host-side
:func:`merge_shard_candidates`), and the fused Pallas kernels.  The
*tiny* builders collapse a small single-column-tile grid into one dense
tile — same arithmetic, no ``lax.scan`` — for the small-program fast
path (see ``docs/engine.md``).

Numerical contract: each executable performs the *same* arithmetic in
the same order as the interpreted tile ops — bit-identical results for
the integer metrics (hamming / dot / packed popcounts / interval
violation counts), float-tolerance for eucl / cos — as pinned by
``repro.kernels.ref``.
"""

from __future__ import annotations

from dataclasses import replace
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec

from ...kernels import packing as kpack
from ...kernels import ref as kref
from ...launch.mesh import make_data_mesh
from .spec import (RangeSpec, SimilaritySpec, _bits, _encode, _metric_values)


def _tile_rows_block(arr: jax.Array, tiles: jax.Array, tr: int,
                     n: int) -> jax.Array:
    """Gather whole row tiles out of a stored operand (jit-traceable).

    Returns the ``(len(tiles) * tr, dim)`` row block covering the given
    row tiles, with slots at/beyond row ``n`` zeroed — exactly the
    content a full prepare lays out for those tiles (it zero-pads
    ragged rows *after* encoding, but every cell encoding maps 0 -> 0,
    so zeroing the raw rows first is equivalent).
    """
    tiles = jnp.asarray(tiles, jnp.int32)
    row_ids = (tiles[:, None] * tr
               + jnp.arange(tr, dtype=jnp.int32)).reshape(-1)
    valid = row_ids < n
    block = jnp.asarray(arr)[jnp.minimum(row_ids, n - 1)]
    return jnp.where(valid[:, None], block, 0)


def _col_dist_fn(spec: SimilaritySpec, packed: bool) -> Callable:
    """Per-column-tile partial distance: ``f(qc, pr) -> (B, tr) float32``.

    ``pr`` is the tuple of per-tile pattern leaves — ``(patterns,)`` or
    ``(patterns, care)`` for ternary.  Unpacked leaves are float slabs
    fed to the oracle arithmetic; packed leaves are uint32 lanes fed to
    XOR+popcount.  Both produce the *same integers* for the integer
    metrics (exact in float32), so the tournament downstream is
    bit-identical whichever representation runs.
    """
    phys_metric, _, _ = _metric_values(spec.metric, spec.largest)
    ternary = spec.care_arg is not None
    if packed:
        def f(qc, pr):
            return kref.packed_distances(qc, pr[0],
                                         pr[1] if ternary else None)
        return f
    if ternary:
        return lambda qc, pr: kref.ternary_distances(qc, pr[0], pr[1])
    return lambda qc, pr: kref.distances(qc, pr[0], phys_metric)


def _tile_tournament(spec: SimilaritySpec, col_dist: Callable,
                     unroll: int = 1):
    """The row-tile tournament shared by the single-device and sharded
    executables: ``scan(qt, pt, roffs)`` runs the column-tile partial-sum
    scan + per-tile top-k + vertical ``merge_topk`` tournament over the
    row tiles in ``pt`` (physical domain), with global row offsets
    ``roffs``.  ``pt`` is a tuple of pattern leaves (see
    :func:`_col_dist_fn`), each ``(gr, gc, tr, lanes-or-dpt)``.  One
    definition keeps every execution path bit-identical by construction.

    Shape-polymorphic in the query batch (read off ``qt``): the
    standard chunked path always traces at the plan's micro-batch, the
    tiny fast path traces at the caller's query count.
    """
    k = spec.k
    _, _, phys_largest = _metric_values(spec.metric, spec.largest)
    tr = spec.tile_rows
    n = spec.n
    kk = min(k, tr)
    lose = -jnp.inf if phys_largest else jnp.inf
    # rows beyond the unsharded physical extent exist only on shard-
    # padding tiles; their candidates become pad_candidates sentinels
    # (a no-op for the single-device grid, which never exceeds it)
    n_phys = spec.grid_rows * tr
    # unroll is a tuning knob, never a semantic one: lax.scan executes
    # identical steps in identical order at any factor.  Clamp to each
    # scan's static length (the sharded executable scans tiles-per-
    # shard, not grid_rows, so the clamp reads the traced operands).
    unroll = max(1, int(unroll))

    def tile_topk(qt, pr, roff):
        """Per-row-tile candidate list (pr leaves: (gc, tr, ...))."""
        batch = qt.shape[1]

        def col_step(acc, xs):
            qc = xs[0]                  # horizontal merge, oracle arithmetic
            return acc + col_dist(qc, xs[1:]), None

        dist, _ = jax.lax.scan(
            col_step, jnp.zeros((batch, tr), jnp.float32), (qt, *pr),
            unroll=min(unroll, qt.shape[0]))
        gidx = roff + jnp.arange(tr, dtype=jnp.int32)
        dist = jnp.where(gidx[None, :] < n, dist, lose)      # ragged rows
        key = dist if phys_largest else -dist
        _, idx = jax.lax.top_k(key, kk)
        v = jnp.take_along_axis(dist, idx, axis=-1)
        i = idx.astype(jnp.int32) + roff
        i = jnp.where(i < n_phys, i, 2 ** 30)
        return kref.pad_candidates(v, i, k, phys_largest)

    def scan(qt, pt, roffs):
        def row_step(carry, xs):
            cv, ci = carry                                   # vertical merge
            tiles, roff = xs
            v, i = tile_topk(qt, tiles, roff)
            return kref.merge_topk(cv, ci, v, i, k=k,
                                   largest=phys_largest), None

        # tile 0 seeds the tournament (its padded-slot indices are real
        # column positions, which the interpreter also reports), remaining
        # row tiles stream through the scan.
        init = tile_topk(qt, tuple(x[0] for x in pt), roffs[0])
        (v, i), _ = jax.lax.scan(
            row_step, init, (tuple(x[1:] for x in pt), roffs[1:]),
            unroll=min(unroll, max(1, pt[0].shape[0] - 1)))
        return v, i

    return scan


def _layout_queries(q, spec, packed: bool = False):
    """Encode + pad + split a query chunk into per-column-tile slabs.

    Packed: each column tile's ``dims_per_tile`` cells pack into their
    own ``ceil(dpt/32)`` uint32 lanes — tiling in **lane units** — so a
    tile's partial count covers exactly the same logical dims as the
    float slab it replaces (tail bits of a tile's last lane are zero in
    queries, patterns, and care masks alike).
    """
    gc, dpt, dim = spec.grid_cols, spec.dims_per_tile, spec.dim
    batch = q.shape[0]
    if packed:
        qb = _bits(q, spec.metric)
        qp = jnp.pad(qb, ((0, 0), (0, gc * dpt - dim)))
        return kpack.pack_bits(qp.reshape(batch, gc, dpt)).transpose(1, 0, 2)
    qe = _encode(q, spec.metric).astype(jnp.float32)
    qp = jnp.pad(qe, ((0, 0), (0, gc * dpt - dim)))
    return qp.reshape(batch, gc, dpt).transpose(1, 0, 2)     # (gc, B, dpt)


def _lay_patterns(p, care, spec, gr_total: int,
                  packed: bool) -> Tuple[jax.Array, ...]:
    """Gallery (+ care mask) laid out as per-subarray tiles.

    Returns the tuple of pattern leaves the tournament scans over:
    ``(patterns,)`` or ``(patterns, care)``, each
    ``(gr_total, gc, tile_rows, dpt-or-lanes)``.  ``gr_total`` exceeds
    ``spec.grid_rows`` only for sharded plans (shard-padding tiles).
    """
    tr, dpt, gc = spec.tile_rows, spec.dims_per_tile, spec.grid_cols
    n, dim = spec.n, spec.dim
    pad = ((0, gr_total * tr - n), (0, gc * dpt - dim))

    def lay(x):
        return x.reshape(gr_total, tr, gc, dpt).transpose(0, 2, 1, 3)

    if packed:
        pe = jnp.pad(_bits(jnp.asarray(p), spec.metric), pad)
        leaves = [kpack.pack_bits(lay(pe))]
        if care is not None:
            ce = jnp.pad(jnp.asarray(care) != 0, pad)
            leaves.append(kpack.pack_bits(lay(ce)))
        return tuple(leaves)
    pe = jnp.pad(_encode(jnp.asarray(p), spec.metric).astype(jnp.float32),
                 pad)
    leaves = [lay(pe)]
    if care is not None:
        ce = jnp.pad((jnp.asarray(care) != 0).astype(jnp.float32), pad)
        leaves.append(lay(ce))
    return tuple(leaves)


def _tile_row_update(spec, packed: bool, placement=None):
    """Row-update closure for the tile-layout executables (jnp + sharded).

    ``update(prepared, srcs, idx)`` re-lays only the row tiles touched
    by ``idx`` — running the *same* encode/pack/layout code a full
    prepare runs, on a ``len(tiles)``-tile slice — and scatters them
    into the prepared leaves.  ``srcs`` are the **post-mutation** stored
    operands, ``(gallery,)`` / ``(gallery, care)`` / ``(lo, hi)``.
    ``placement`` (sharded plans) re-pins each updated leaf to the mesh
    so every rewritten tile lands back on its owning shard.
    """
    def relay(prepared, srcs, tiles):
        # tiles has static length under jit; the jit cache retraces per
        # touched-tile count, which a retraining loop repeats constantly
        nt = tiles.shape[0]
        tspec = replace(spec, n=nt * spec.tile_rows)
        blocks = [_tile_rows_block(s, tiles, spec.tile_rows, spec.n)
                  for s in srcs]
        if isinstance(spec, SimilaritySpec):
            fresh = _lay_patterns(blocks[0],
                                  blocks[1] if len(blocks) > 1 else None,
                                  tspec, nt, packed)
        else:
            fresh = _lay_range_patterns(blocks, tspec, nt, packed)
        return tuple(leaf.at[tiles].set(f.astype(leaf.dtype))
                     for leaf, f in zip(prepared, fresh))

    # the donating variant scatters the fresh tiles into the old
    # prepared leaves' buffers in place (the caller just invalidated
    # the old layout — see update_rows(donate=True))
    relay_jit = jax.jit(relay)
    relay_don = jax.jit(relay, donate_argnums=0)

    def update(prepared, srcs, idx, donate=False):
        tiles = np.unique(np.asarray(idx, np.int64) // spec.tile_rows)
        fn = relay_don if donate else relay_jit
        out = fn(tuple(prepared), tuple(srcs), jnp.asarray(tiles, jnp.int32))
        if placement is not None:
            out = tuple(jax.device_put(x, placement) for x in out)
        return out

    return update


def _row_scatter_update(spec, packed: bool, interval: bool = False):
    """Row-update closure for the pallas executables, whose prepared
    layout is the block-padded 2-D operand itself: encode/pack just the
    touched rows and scatter them (padding lanes/columns stay zero)."""
    def relay(prepared, srcs, j):
        out = []
        for leaf, s in zip(prepared, srcs):
            rows = jnp.asarray(s)[j]
            if packed:
                enc = kpack.pack_bits(_bits(rows, spec.metric))
            elif interval:
                enc = rows.astype(jnp.float32)
            else:
                enc = _encode(rows, spec.metric).astype(jnp.float32)
            enc = jnp.pad(enc, ((0, 0), (0, leaf.shape[1] - enc.shape[1])))
            out.append(leaf.at[j].set(enc.astype(leaf.dtype)))
        return tuple(out)

    relay_jit = jax.jit(relay)
    relay_don = jax.jit(relay, donate_argnums=0)

    def update(prepared, srcs, idx, donate=False):
        fn = relay_don if donate else relay_jit
        return fn(tuple(prepared), tuple(srcs),
                  jnp.asarray(np.asarray(idx, np.int64)))

    return update


# ---------------------------------------------------------------------------
# Similarity executables
# ---------------------------------------------------------------------------


def _build_scan_executable(spec: SimilaritySpec, batch: int,
                           packed: bool = False, unroll: int = 1):
    """(prepare_patterns, chunk_fn, row_update) for the jnp
    (reference-tiled) backend.

    ``chunk_fn`` mirrors ``kernels.ref.cam_topk_tiled`` exactly — same
    partial-sum order, same stable top-k and tournament merges — but as a
    ``jax.lax.scan`` over the (row_tile, col_tile) grid, so the jaxpr
    stays small at any grid size and XLA pipelines the tiles.  With
    ``packed=True`` the same scan runs over uint32 lane tiles
    (XOR+popcount partial counts) — identical integers, 1/32nd the
    resident gallery.
    """
    _, to_logical, _ = _metric_values(spec.metric, spec.largest)
    gr, dim = spec.grid_rows, spec.dim
    scan = _tile_tournament(spec, _col_dist_fn(spec, packed), unroll)

    def prepare(p, care=None):
        return _lay_patterns(p, care, spec, gr, packed)

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, packed)
        roffs = jnp.arange(gr, dtype=jnp.int32) * spec.tile_rows
        v, i = scan(qt, pt, roffs)
        return to_logical(v, float(dim)), i

    return jax.jit(prepare), jax.jit(chunk_fn), _tile_row_update(spec, packed)


def _dense_spec(spec):
    """The one-tile equivalent of a single-column-tile spec: the whole
    (physically padded) gallery as one ``(grid_rows * tile_rows, dim)``
    tile.  Dense and tiled execution are bit-identical for such specs —
    each row's value is one full-width distance either way, and a stable
    dense top-k selects exactly what the tile tournament's stable merges
    select — so the tiny executables simply reuse the tiled builders on
    this derived spec (including their row-update closures, whose tile
    granularity becomes "all rows").
    """
    if spec.grid_cols != 1:
        raise ValueError("dense fast path requires grid_cols == 1")
    return replace(spec, tile_rows=spec.grid_rows * spec.tile_rows,
                   grid_rows=1, dims_per_tile=spec.dim)


def _build_tiny_executable(spec: SimilaritySpec, batch: int,
                           packed: bool = False, unroll: int = 1):
    """Dense one-tile executable for tiny similarity plans.

    Small programs (ROADMAP item 5: the forest ``t32_d4`` point ran at
    0.43x of the interpreter) spend their time in per-tile ``lax.scan``
    stepping, not arithmetic; collapsing the grid into one dense tile
    removes the scan entirely while keeping the exact tournament
    semantics (see :func:`_dense_spec`).
    """
    return _build_scan_executable(_dense_spec(spec), batch, packed=packed,
                                  unroll=unroll)


def _build_sharded_executable(spec: SimilaritySpec, batch: int, shards: int,
                              packed: bool = False, unroll: int = 1):
    """(prepare_patterns, chunk_fn, row_update) sharding gallery rows
    over a device mesh.

    Device ``d`` holds row tiles ``[d*tps, (d+1)*tps)`` of the padded
    gallery (``tps = ceil(grid_rows / shards)``) and runs the *same*
    row-tile scan as the single-device executable over its shard — the
    bank level of the paper's hierarchy.  ``chunk_fn`` returns the
    per-device candidate lists still *sharded* ``(shards, batch, k)``;
    the cross-device tournament happens in :func:`merge_shard_candidates`
    at result-materialisation time.

    The per-device program deliberately contains **no collective**: an
    ``all_gather`` at the tail of each chunk would make every device's
    stream rendezvous with the slowest shard before its next chunk could
    start, serialising the pipeline exactly where the serving layer
    needs overlap.  Collective-free shard programs let each device run
    chunk after chunk back-to-back; the merge is O(shards·k) per query
    and runs off-stream.

    Padding tiles introduced by uneven division live *beyond* the
    single-device physical row count ``grid_rows * tile_rows``; their
    candidates are rewritten to the ``pad_candidates`` sentinels
    (losing value, index ``2**30``) so a sharded plan emits bit-identical
    output to the unsharded one even when ``n < k`` leaves losing slots
    visible.
    """
    _, to_logical, _ = _metric_values(spec.metric, spec.largest)
    tr, gr = spec.tile_rows, spec.grid_rows
    dim = spec.dim
    mesh = make_data_mesh(shards)
    tps = -(-gr // shards)          # row tiles per shard
    gr_pad = shards * tps
    scan = _tile_tournament(spec, _col_dist_fn(spec, packed), unroll)

    def prepare(p, care=None):
        pt = _lay_patterns(p, care, spec, gr_pad, packed)
        # lay the row-tile axis out over the mesh once, behind the plan
        # cache — chunk execution never re-shards the gallery
        sh = NamedSharding(mesh, PartitionSpec("data"))
        return tuple(jax.device_put(x, sh) for x in pt)

    def local_scan(qt, pt):
        """One device's shard of the row-tile tournament (no collectives)."""
        d = jax.lax.axis_index("data")
        roffs = (d * tps + jnp.arange(tps, dtype=jnp.int32)) * tr
        v, i = scan(qt, pt, roffs)
        # logical-domain conversion is elementwise and strictly monotone,
        # so the host-side merge can run directly on logical values with
        # the logical polarity and still match the physical tournament
        return to_logical(v, float(dim))[None], i[None]   # (1, B, k)

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, packed)
        # PartitionSpec("data") applies prefix-wise to every pattern leaf
        return shard_map(
            local_scan, mesh=mesh,
            in_specs=(PartitionSpec(), PartitionSpec("data")),
            out_specs=(PartitionSpec("data"), PartitionSpec("data")),
            check_rep=False)(qt, pt)                          # (S, B, k)

    sh = NamedSharding(mesh, PartitionSpec("data"))
    return prepare, jax.jit(chunk_fn), _tile_row_update(spec, packed,
                                                        placement=sh)


def merge_shard_candidates(values: Any, indices: Any, *, k: int,
                           largest: bool) -> Tuple[Any, Any]:
    """Cross-shard top-k tournament, host-side.

    Takes the ``(shards, batch, k)`` per-device candidate lists a sharded
    ``chunk_fn`` emits and reduces them to ``(batch, k)``.  Semantically
    identical to folding :func:`kref.merge_topk` over shards in ascending
    order: concatenation in shard order is concatenation in ascending
    global-row order, and a *stable* argsort on the (negated, for
    ``largest``) values breaks ties toward the lower global index exactly
    like ``lax.top_k`` does in the on-device merges.  No arithmetic
    happens here — only selection on already-computed values — so
    integer-metric results stay bit-identical to the single-device plan.
    """
    av = np.asarray(values)
    ai = np.asarray(indices)
    s, b, kk = av.shape
    vv = np.transpose(av, (1, 0, 2)).reshape(b, s * kk)
    ii = np.transpose(ai, (1, 0, 2)).reshape(b, s * kk)
    key = -vv if largest else vv
    sel = np.argsort(key, axis=-1, kind="stable")[:, :k]
    return (np.take_along_axis(vv, sel, axis=-1),
            np.take_along_axis(ii, sel, axis=-1))


def _build_pallas_executable(spec: SimilaritySpec, batch: int,
                             packed: bool = False):
    """(prepare_patterns, chunk_fn, row_update) driving the fused
    Pallas kernels.

    Pattern encoding and block padding run once per stored array (hoisted
    behind the plan cache) instead of on every ``cam_topk`` call.  With
    ``packed=True`` the packed XOR+popcount kernel runs over uint32
    lanes (lane-blocked grid) instead of the float MXU decomposition —
    candidates are bit-identical either way.
    """
    from ...kernels import ops as kops

    metric, k = spec.metric, spec.k
    phys_metric, to_logical, phys_largest = _metric_values(metric, spec.largest)
    n, dim = spec.n, spec.dim
    ternary = spec.care_arg is not None
    k_eff = min(k, n)
    bn = max(8, min(spec.tile_rows, n))
    bd = min(spec.dims_per_tile, dim)
    bm = min(128, max(8, batch))
    bl = max(1, min(kpack.lanes(bd), kpack.lanes(dim)))  # lane-unit tiling

    def prepare(p, care=None):
        if packed:
            pp = kops.pad_to_blocks(
                kpack.pack_bits(_bits(jnp.asarray(p), metric)), bn, bl)
            if care is None:
                return (pp,)
            cp = kops.pad_to_blocks(
                kpack.pack_bits(jnp.asarray(care) != 0), bn, bl)
            return (pp, cp)
        pe = _encode(jnp.asarray(p), metric).astype(jnp.float32)
        return (kops.pad_to_blocks(pe, bn, bd),)

    def chunk_fn(q, pp):
        if packed:
            qp = kops.pad_to_blocks(
                kpack.pack_bits(_bits(q, metric)), bm, bl)
            v, i = kops.cam_topk_packed_prepadded(
                qp, pp[0], pp[1] if ternary else None, k=k_eff,
                largest=phys_largest, n_valid=n, block_m=bm, block_n=bn,
                block_l=bl)
        else:
            qe = _encode(q, metric).astype(jnp.float32)
            qp = kops.pad_to_blocks(qe, bm, bd)
            v, i = kops.cam_topk_prepadded(
                qp, pp[0], metric=phys_metric, k=k_eff,
                largest=phys_largest, n_valid=n, block_m=bm, block_n=bn,
                block_d=bd)
        b = q.shape[0]
        v, i = kref.pad_candidates(v[:b], i[:b], k, phys_largest)
        return to_logical(v, float(dim)), i

    return jax.jit(prepare), jax.jit(chunk_fn), _row_scatter_update(spec,
                                                                    packed)


# ---------------------------------------------------------------------------
# Range-search executables (boolean match: TH threshold / aCAM interval)
# ---------------------------------------------------------------------------


def _range_col_fn(spec: RangeSpec, packed: bool) -> Callable:
    """Per-column-tile partial value for a range program.

    Threshold mode accumulates the same physical distances the search
    path uses (packed popcounts included); interval mode accumulates
    aCAM *violation counts* — ``(q < lo) | (q > hi)`` per cell, summed.
    Both are additive over column tiles, so the scan reproduces the
    dense oracle exactly (integer counts) or in identical float order
    (eucl, mirroring :func:`kref.tiled_distances`).
    """
    if spec.mode == "interval":
        # the pinned oracle IS the per-tile function: violation counts
        # are additive over dimension tiles by construction
        return lambda qc, pr: kref.acam_violations(qc, pr[0], pr[1])
    phys_metric, _, _ = _metric_values(spec.metric, True)
    if packed:
        return lambda qc, pr: kref.packed_distances(qc, pr[0])
    return lambda qc, pr: kref.distances(qc, pr[0], phys_metric)


def _range_tile_scan(spec: RangeSpec, col_fn: Callable, unroll: int = 1):
    """Row-tile scan for range programs: ``scan(qt, pt)`` accumulates
    each row tile's physical value over the column tiles and returns
    the stacked ``(n_tiles, batch, tile_rows)`` value blocks.  No
    tournament — every stored row keeps its own match line.  Shape-
    polymorphic in the query batch, like :func:`_tile_tournament`
    (whose unroll-clamp rationale also applies here)."""
    tr = spec.tile_rows
    unroll = max(1, int(unroll))

    def tile_value(qt, pr):
        batch = qt.shape[1]

        def col_step(acc, xs):
            return acc + col_fn(xs[0], xs[1:]), None

        dist, _ = jax.lax.scan(
            col_step, jnp.zeros((batch, tr), jnp.float32), (qt, *pr),
            unroll=min(unroll, qt.shape[0]))
        return dist

    def scan(qt, pt):
        def row_step(carry, xs):
            return carry, tile_value(qt, xs)

        _, dists = jax.lax.scan(row_step, None, pt,
                                unroll=min(unroll, max(1, pt[0].shape[0])))
        return dists                                    # (gr, B, tr)

    return scan


def _range_compare(spec: RangeSpec):
    """Value block -> boolean match block, in the logical metric domain."""
    if spec.mode == "interval":
        return lambda d: d == 0
    _, to_logical, _ = _metric_values(spec.metric, True)
    tau, below, dim = spec.threshold, spec.below, float(spec.dim)
    if below:
        return lambda d: to_logical(d, dim) <= tau
    return lambda d: to_logical(d, dim) >= tau


def _lay_range_patterns(pats, spec: RangeSpec, gr_total: int,
                        packed: bool) -> Tuple[jax.Array, ...]:
    """Stored operands laid out as per-subarray tiles.

    ``(patterns,)`` or ``(lo, hi)``, each ``(gr_total, gc, tr, X)``.
    Zero padding is interval-safe: padded dims carry ``q = lo = hi =
    0`` (never a violation) and padded rows land beyond ``spec.n``,
    where finalize slices them off.
    """
    leaves = []
    for p in pats:
        leaves.extend(_lay_patterns(p, None, spec, gr_total, packed))
    return tuple(leaves)


def _build_range_scan_executable(spec: RangeSpec, batch: int,
                                 packed: bool = False, unroll: int = 1):
    """(prepare, chunk_fn, row_update) for the jnp range path: chunk_fn
    returns the ``(batch, grid_rows * tile_rows)`` boolean match block."""
    gr = spec.grid_rows
    scan = _range_tile_scan(spec, _range_col_fn(spec, packed), unroll)
    compare = _range_compare(spec)

    def prepare(*pats):
        return _lay_range_patterns(pats, spec, gr, packed)

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, packed)
        d = scan(qt, pt)                                 # (gr, B, tr)
        hit = compare(d)
        return hit.transpose(1, 0, 2).reshape(q.shape[0], -1)

    return jax.jit(prepare), jax.jit(chunk_fn), _tile_row_update(spec, packed)


def _build_tiny_range_executable(spec: RangeSpec, batch: int,
                                 packed: bool = False, unroll: int = 1):
    """Dense one-tile executable for tiny range plans (the forest
    small-program case) — the range twin of
    :func:`_build_tiny_executable`."""
    return _build_range_scan_executable(_dense_spec(spec), batch,
                                        packed=packed, unroll=unroll)


def _build_range_sharded_executable(spec: RangeSpec, batch: int, shards: int,
                                    packed: bool = False, unroll: int = 1):
    """(prepare, chunk_fn, row_update) sharding stored rows over a
    device mesh.

    Same bank-level row split as the sharded search executable, but the
    per-device outputs are boolean match slices that simply
    *concatenate* in shard order (== ascending global row order) at
    finalize — range search has no cross-shard tournament, so the
    per-device program is trivially collective-free.
    """
    tr, gr = spec.tile_rows, spec.grid_rows
    mesh = make_data_mesh(shards)
    tps = -(-gr // shards)
    gr_pad = shards * tps
    scan = _range_tile_scan(spec, _range_col_fn(spec, packed), unroll)
    compare = _range_compare(spec)

    def prepare(*pats):
        pt = _lay_range_patterns(pats, spec, gr_pad, packed)
        sh = NamedSharding(mesh, PartitionSpec("data"))
        return tuple(jax.device_put(x, sh) for x in pt)

    def local_scan(qt, pt):
        d = scan(qt, pt)                                 # (tps, B, tr)
        hit = compare(d)
        return hit.transpose(1, 0, 2).reshape(qt.shape[1], tps * tr)[None]

    def chunk_fn(q, pt):
        qt = _layout_queries(q, spec, packed)
        return shard_map(
            local_scan, mesh=mesh,
            in_specs=(PartitionSpec(), PartitionSpec("data")),
            out_specs=PartitionSpec("data"),
            check_rep=False)(qt, pt)                     # (S, B, tps*tr)

    sh = NamedSharding(mesh, PartitionSpec("data"))
    return prepare, jax.jit(chunk_fn), _tile_row_update(spec, packed,
                                                        placement=sh)


def _build_range_pallas_executable(spec: RangeSpec, batch: int):
    """(prepare, chunk_fn, row_update) driving the fused aCAM /
    threshold kernels.

    The match threshold (or the ``violations == 0`` test) happens at
    block-extraction time inside the kernel — only an int8 matrix
    leaves it.  Unpacked operands only (the packed popcount path lives
    in the jnp executable).
    """
    from ...kernels import ops as kops

    n, dim = spec.n, spec.dim
    bn = max(8, min(spec.tile_rows, n))
    bd = min(spec.dims_per_tile, dim)
    bm = min(128, max(8, batch))
    interval = spec.mode == "interval"
    if not interval:
        phys_metric, _, _ = _metric_values(spec.metric, True)
        to_logical = "bipolar" if spec.metric in ("dot", "cos") \
            else "identity"

    def prepare(*pats):
        if interval:
            return tuple(
                kops.pad_to_blocks(jnp.asarray(p).astype(jnp.float32),
                                   bn, bd)
                for p in pats)
        pe = _encode(jnp.asarray(pats[0]), spec.metric).astype(jnp.float32)
        return (kops.pad_to_blocks(pe, bn, bd),)

    def chunk_fn(q, pp):
        if interval:
            qp = kops.pad_to_blocks(q.astype(jnp.float32), bm, bd)
            hit = kops.acam_match_prepadded(
                qp, pp[0], pp[1], n_valid=n, block_m=bm, block_n=bn,
                block_d=bd)
        else:
            qe = _encode(q, spec.metric).astype(jnp.float32)
            qp = kops.pad_to_blocks(qe, bm, bd)
            hit = kops.cam_range_match_prepadded(
                qp, pp[0], metric=phys_metric, threshold=spec.threshold,
                below=spec.below, to_logical=to_logical, dim=dim,
                n_valid=n, block_m=bm, block_n=bn, block_d=bd)
        return hit[:q.shape[0]] != 0

    return jax.jit(prepare), jax.jit(chunk_fn), _row_scatter_update(
        spec, packed=False, interval=interval)
