"""The leaf plan families: :class:`SearchPlan` and :class:`RangePlan`.

Thin subclasses of :class:`~.base.PlanBase` — each defines only its
family's structure: which module arguments are stored operands, the
shape of a chunk record, how chunks finalize into the module's output,
and the public ``update_rows`` signature.  Everything else (micro-batch
dispatch, pattern memoisation, fault hooks, the incremental-update
relay) is inherited.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ...obs.trace import trace_span
from .base import PendingSearch, PlanBase, _size
from .executables import merge_shard_candidates

__all__ = ["SearchPlan", "RangePlan"]


@dataclass
class SearchPlan(PlanBase):
    """A compiled, reusable executable for one similarity-program shape.

    Chunks hold ``(values, indices, valid_rows)``; finalize runs the
    cross-shard candidate merge (sharded plans), slices ragged tails,
    and shapes ``(values, indices)`` for the compiled module.
    """

    family: str = field(default="search", repr=False)

    def _stored_sources(self, inputs) -> Tuple:
        spec = self.spec
        if spec.care_arg is None:
            return (inputs[spec.pattern_arg],)
        return (inputs[spec.pattern_arg], inputs[spec.care_arg])

    def _chunk_entry(self, out, valid: int):
        v, i = out
        return (v, i, valid)

    def finalize(self, pending: "PendingSearch"):
        """Materialise a dispatched search: cross-shard merge (sharded
        plans), ragged-tail slicing, chunk concatenation, output shaping."""
        with trace_span("plan.finalize"):
            return self._finalize(pending)

    def _finalize(self, pending: "PendingSearch"):
        spec = self.spec
        xp = np if self.shards > 1 else jnp
        vs, is_ = [], []
        for v, i, valid in pending.chunks:
            if self.shards > 1:
                v, i = merge_shard_candidates(v, i, k=spec.k,
                                              largest=spec.largest)
            vs.append(v[:valid])
            is_.append(i[:valid])
        if not vs:      # zero queries: well-shaped empty result
            vs = [xp.zeros((0, spec.k), xp.float32)]
            is_ = [xp.zeros((0, spec.k), xp.int32)]
        v = vs[0] if len(vs) == 1 else xp.concatenate(vs, axis=0)
        i = is_[0] if len(is_) == 1 else xp.concatenate(is_, axis=0)

        m, lead, k = pending.m, pending.lead, spec.k
        if m * k == _size(spec.out_v_shape):
            v = v.reshape(spec.out_v_shape)
            i = i.reshape(spec.out_i_shape)
        else:   # runtime M differs from the traced shape: mirror _as_2d
            v = v.reshape(lead + (k,))
            i = i.reshape(lead + (k,))
        return (v, i)

    # -- gallery mutation --------------------------------------------------

    def update_rows(self, gallery, indices, new_rows, care=None, *,
                    donate: bool = False):
        """Row-granular gallery mutation with incremental re-preparation.

        Returns the updated gallery as a fresh immutable ``jax.Array``
        whose prepared layout was derived from ``gallery``'s memoised
        layout by rewriting only the row tiles ``indices`` touch —
        encode/pack/layout runs on those tiles alone (sharded plans
        re-pin the leaves so each tile lands on its owning shard), so an
        online-learning workload touching 1% of a large gallery skips
        ~99% of the re-prepare work.  Results are bit-identical to a
        full re-prepare of the mutated gallery.

        ``care`` must be the plan's care mask for ternary programs (the
        memo keys on the (gallery, care) pair; the mask itself is
        immutable).  If ``gallery``'s layout is not memoised — numpy
        source, never dispatched, or evicted — the mutation still
        happens and the next dispatch re-prepares in full (counted in
        ``row_update_fallbacks``).

        ``donate=True`` reuses ``gallery``'s device buffer for the
        mutation (in-place scatter instead of a full-gallery copy —
        the copy otherwise dominates large-gallery updates).  Only pass
        it when nothing else will read ``gallery`` afterwards: the old
        array is invalidated, exactly like jit donation.
        """
        spec = self.spec
        if (care is None) != (spec.care_arg is None):
            raise ValueError("care mask must be passed iff the plan's "
                             "program is ternary")
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        self._validate_update(idx, new_rows)
        olds = (gallery,) if care is None else (gallery, care)
        # only the gallery rows mutate; a ternary care mask passes through
        upd = self._mutate_stored(olds, (new_rows,), idx, donate)
        return upd[0]


@dataclass
class RangePlan(PlanBase):
    """A compiled, reusable executable for one range-search program.

    Same plan-cache citizenship, micro-batching, pattern memoisation,
    packing and sharding as :class:`SearchPlan`; the result is a single
    ``(M, N)`` boolean match matrix instead of ``(values, indices)``.
    ``spec`` is a :class:`~.spec.RangeSpec`; chunks hold
    ``(match, valid_rows)``.
    """

    family: str = field(default="range", repr=False)

    def _stored_sources(self, inputs) -> Tuple:
        return tuple(inputs[i] for i in self.spec.pattern_args)

    def _chunk_entry(self, out, valid: int):
        return (out, valid)

    def finalize(self, pending: "PendingSearch"):
        """Materialise a dispatched range search into the boolean match
        matrix: concatenate per-shard slices (shard order == ascending
        global row order — no tournament), drop padded rows/chunks,
        shape for the compiled module."""
        with trace_span("plan.finalize"):
            return self._finalize(pending)

    def _finalize(self, pending: "PendingSearch"):
        spec = self.spec
        xp = np if self.shards > 1 else jnp
        outs = []
        for hit, valid in pending.chunks:
            if self.shards > 1:
                h = np.asarray(hit)                       # (S, B, cols)
                h = np.transpose(h, (1, 0, 2)).reshape(h.shape[1], -1)
            else:
                h = hit
            outs.append(h[:valid, :spec.n])
        if not outs:    # zero queries: well-shaped empty result
            outs = [xp.zeros((0, spec.n), bool)]
        match = outs[0] if len(outs) == 1 else xp.concatenate(outs, axis=0)
        m, lead = pending.m, pending.lead
        if m * spec.n == _size(spec.out_shape):
            return match.reshape(spec.out_shape)
        return match.reshape(lead + (spec.n,))

    def update_rows(self, stored, indices, new_rows, care=None, *,
                    donate: bool = False):
        """Row-granular mutation of a range plan's stored operands.

        ``stored`` is the current stored content — the pattern array
        for threshold mode, the ``(lo, hi)`` pair for interval mode —
        and ``new_rows`` matches that structure with ``(len(indices),
        dim)`` row blocks.  Returns the updated operand(s) in the same
        structure (jax arrays), memo-seeded incrementally exactly like
        :meth:`SearchPlan.update_rows` (including the ``donate``
        buffer-reuse contract).
        """
        if care is not None:
            raise ValueError("range plans have no care operand")
        spec = self.spec
        multi = len(spec.pattern_args) == 2
        olds = tuple(stored) if multi else (stored,)
        news = tuple(new_rows) if multi else (new_rows,)
        if len(olds) != len(spec.pattern_args) or len(news) != len(olds):
            raise ValueError(
                f"expected {len(spec.pattern_args)} stored operand(s) "
                f"and matching new-row block(s)")
        idx = np.atleast_1d(np.asarray(indices, np.int64))
        self._validate_update(idx, *news)
        upd = self._mutate_stored(olds, news, idx, donate)
        return upd if multi else upd[0]
