"""Plan specs and the structural IR analysis that produces them.

A *spec* is the frozen, hashable structural summary of a partitioned
``cim`` program — metric, k/threshold, tile geometry, operand wiring and
output shapes.  Two modules with equal specs compile to interchangeable
executables; the spec (plus backend / micro-batch / shards / packing)
*is* the plan-cache key.  Three spec families live here and in
:mod:`.composite`:

* :class:`SimilaritySpec` — top-k similarity search;
* :class:`RangeSpec` — boolean match search (threshold / aCAM interval);
* ``HierarchicalSpec`` (:mod:`.composite`) — a two-stage coarse→fine
  composition wrapping a fine :class:`SimilaritySpec`.

Also here: the metric/encoding helpers mapping the physical CAM domain
(hamming counts, violation counts) to the logical metric domain, and
:func:`module_for_spec`, which round-trips a spec back to IR.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional, Tuple
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..envcfg import env_flag
from ..ir import Module


# ---------------------------------------------------------------------------
# Metric / encoding helpers (physical CAM domain <-> logical metric domain)
# ---------------------------------------------------------------------------


def _metric_values(metric: str, largest: bool):
    """How the physical CAM search relates to the logical metric."""
    if metric in ("dot", "cos"):
        # bipolar: argmax dot == argmin hamming; report dot values
        return "hamming", (lambda h, dim: dim - 2.0 * h), (not largest)
    if metric == "eucl":
        return "eucl", (lambda d, dim: d), largest
    if metric == "hamming":
        return "hamming", (lambda h, dim: h), largest
    raise ValueError(metric)


def _encode(x: jax.Array, metric: str) -> jax.Array:
    if metric in ("dot", "cos", "hamming"):
        return (x > 0).astype(jnp.float32) if metric != "hamming" else x
    return x


def _bits(x: jax.Array, metric: str) -> jax.Array:
    """Cell bits for the packed path (bool array, unpacked).

    ``dot``/``cos`` binarise exactly like :func:`_encode` (``x > 0``),
    so the packed path sees the same cells as the float path for *any*
    real input.  ``hamming`` inputs are {0, 1} by the kernel contract
    (see ``kernels/ref.py``); the bit is ``x != 0``, which coincides
    with the unpacked mismatch count on contract-conforming data —
    packed hamming plans *enforce* the contract at dispatch time
    (:func:`_check_binary_cells`) because collapsing a richer alphabet
    to bits would silently change results.
    """
    return (x > 0) if metric in ("dot", "cos") else (x != 0)


def _check_binary_cells(x, what: str) -> None:
    """Packed-hamming contract guard: values must be {0, 1} / booleans.

    The unpacked path computes a true elementwise mismatch count for
    *any* alphabet; the packed path only sees bits.  Rather than let
    bipolar or multi-bit data (e.g. {-1, +1}, value_bits > 1 cells)
    silently collapse to all-match, reject it here — one host-side pass
    over data the pack step reads anyway (galleries only on a memo
    miss).  ``pack=False`` keeps the general float path for such data.
    """
    a = np.asarray(x)
    if a.dtype == np.bool_:
        return
    if not bool(((a == 0) | (a == 1)).all()):
        raise ValueError(
            f"packed hamming search requires binary {{0, 1}} {what} "
            f"(got values outside the CAM cell contract); pass "
            f"pack=False to run the float path on non-binary data")


#: metrics with a bit-packed physical search (binary cells, integer counts)
_PACKABLE_METRICS = ("hamming", "dot", "cos")


def _resolve_pack(spec, pack: Optional[bool]) -> bool:
    """Effective packing choice for a plan.

    ``None`` (auto) packs every packable metric — the physical search is
    bit-identical either way, and the packed gallery is 32x smaller —
    unless ``REPRO_ENGINE_PACK`` is ``off``/``0``.  An explicit
    ``pack=True`` on an analog metric is a hard error: euclidean
    distances have no binary cell encoding.
    """
    packable = spec.metric in _PACKABLE_METRICS
    if pack is None:
        return packable and env_flag("REPRO_ENGINE_PACK", True)
    if pack and not packable:
        raise ValueError(
            f"packed execution requires a binary/bipolar metric "
            f"(hamming/dot/cos), got {spec.metric!r}")
    return bool(pack)


# ---------------------------------------------------------------------------
# Plan specs: everything a compiled search needs, hashable for the cache
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimilaritySpec:
    """Structural summary of a partitioned similarity program.

    Two modules with equal specs compile to interchangeable executables;
    the spec (plus backend and micro-batch size) *is* the plan-cache key.
    """

    metric: str
    k: int
    largest: bool              # logical polarity (metric domain)
    tile_rows: int             # R: pattern rows per subarray
    dims_per_tile: int         # logical values per column tile
    grid_rows: int
    grid_cols: int
    m: int                     # traced query count (batch hint only)
    n: int                     # pattern rows
    dim: int                   # logical feature dimension
    query_arg: int             # positions in module.arguments
    pattern_arg: int
    out_v_shape: Tuple[int, ...]
    out_i_shape: Tuple[int, ...]
    #: TCAM ternary search: module-argument position of the per-pattern
    #: care mask ((N, D), non-zero = compared cell, 0 = wildcard)
    care_arg: Optional[int] = None
    #: IR dtypes of the (query, pattern[, care]) operands.  Part of the
    #: plan key: with packed uint32 operands in play, two programs with
    #: identical geometry but different operand dtypes must not share an
    #: executable.
    in_dtypes: Tuple[str, ...] = ("f32", "f32")


@dataclass(frozen=True)
class RangeSpec:
    """Structural summary of a partitioned range-search program.

    The second plan family: boolean match search (paper TH mode /
    analog-CAM interval match) instead of top-k.  Shares the plan
    cache, tile geometry, micro-batching, pattern memoisation, packing
    and sharding machinery with :class:`SimilaritySpec` plans; being a
    distinct (frozen, hashable) type, its cache keys can never collide
    with a similarity plan's.
    """

    #: "threshold" (distance vs tau) or "interval" (aCAM lo/hi cells)
    mode: str
    #: logical metric for threshold mode; the sentinel "interval" for
    #: interval mode (not packable, encoding is a passthrough)
    metric: str
    threshold: float           # static: part of the plan key
    below: bool                # True: match iff value <= tau; False: >=
    tile_rows: int
    dims_per_tile: int
    grid_rows: int
    grid_cols: int
    m: int                     # traced query count (batch hint only)
    n: int                     # stored rows
    dim: int
    query_arg: int
    #: module-argument positions of the stored operands — (patterns,)
    #: for threshold mode, (lo, hi) for interval mode
    pattern_args: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    in_dtypes: Tuple[str, ...] = ("f32", "f32")

    def __post_init__(self):
        # float-field canonicalisation: the spec is the plan-cache key
        # AND the source of the on-disk store digest.  -0.0 == 0.0 in
        # Python (one dict slot) but repr differs, which would let two
        # digests alias one plan; NaN is worse — a NaN spec is unequal
        # to *itself*, so its plan could never be cache-hit (and NaN
        # thresholds match nothing anyway).  ``+ 0.0`` maps -0.0 to
        # +0.0 and leaves every other value bit-unchanged.
        t = float(self.threshold)
        if t != t:
            raise ValueError(
                "RangeSpec threshold must not be NaN (a NaN threshold "
                "matches no row and poisons the plan-cache key)")
        object.__setattr__(self, "threshold", t + 0.0)


# ---------------------------------------------------------------------------
# Stable spec digests (the persistent plan store's on-disk keys)
# ---------------------------------------------------------------------------


def _fingerprint_value(o):
    """Canonical JSON-able form of one spec field value.

    Floats are tagged and rendered via ``repr`` *after* ``+ 0.0``
    (mapping -0.0 to +0.0, matching the ``RangeSpec`` canonicalisation)
    so the digest of a float field is exactly as wide as Python ``==``
    on the canonicalised spec — two specs that share a plan-cache slot
    share a digest, and vice versa.  NaN raises: a digest that aliases
    "matches nothing" onto a real plan would silently serve the wrong
    executable from disk.
    """
    if dataclasses.is_dataclass(o) and not isinstance(o, type):
        out = {"__family__": type(o).__name__}
        for f in dataclasses.fields(o):
            out[f.name] = _fingerprint_value(getattr(o, f.name))
        return out
    if isinstance(o, bool) or o is None or isinstance(o, (int, str)):
        return o
    if isinstance(o, float):
        v = float(o) + 0.0
        if v != v:
            raise ValueError("cannot fingerprint a NaN spec field")
        return {"__float__": repr(v)}
    if isinstance(o, (tuple, list)):
        return [_fingerprint_value(x) for x in o]
    raise TypeError(
        f"unfingerprintable spec field of type {type(o).__name__}")


def spec_fingerprint(spec) -> str:
    """Deterministic, family-tagged canonical JSON for a plan spec.

    Covers every dataclass field (nested specs included, so a
    ``HierarchicalSpec`` fingerprints its fine spec recursively); the
    family tag keeps a ``RangeSpec`` and a ``SimilaritySpec`` with
    coincidentally-aligned fields from ever sharing a digest, mirroring
    the type-split of the in-memory plan-cache key.
    """
    return json.dumps(_fingerprint_value(spec), sort_keys=True,
                      separators=(",", ":"))


def spec_digest(spec) -> str:
    """sha256 hex of :func:`spec_fingerprint` — the stable on-disk key
    the persistent plan store files its configs/executables under."""
    return hashlib.sha256(spec_fingerprint(spec).encode()).hexdigest()


def workload_digest(spec) -> str:
    """Digest of the spec with its tile geometry normalised away.

    The autotuner *searches over* tile geometry, so tuned configs must
    be keyed by what the workload IS (metric, k/threshold, operand
    shapes, dtypes, care wiring) rather than how one particular module
    happened to tile it — otherwise a config tuned from a rows=16 arch
    would be invisible to the same workload partitioned at rows=64.
    """
    geomless = dataclasses.replace(spec, tile_rows=0, dims_per_tile=0,
                                   grid_rows=0, grid_cols=0)
    return spec_digest(geomless)


_SIM_OPS = {"cim.similarity", "cim.tiled_similarity"}
_TILE_OPS = {"cim.search_tile", "cim.merge_partial", "cim.topk_tile",
             "cim.reshape_result"}
_RANGE_OPS = {"cim.range_search", "cim.tiled_range_search"}


def extract_plan_spec(module: Module) -> Optional[SimilaritySpec]:
    """Return the spec if ``module`` is a pure similarity program.

    Accepted shape: ``cim.acquire`` / one ``cim.execute`` whose region is a
    single fused (or partitioned) similarity / ``cim.release`` /
    ``func.return`` of the execute's two results.  Host ops, multiple
    similarities, or operands that are not module arguments all return
    ``None`` (the interpreter remains the general path).
    """
    args = module.arguments
    arg_pos = {id(a): i for i, a in enumerate(args)}
    execute = None
    ret = None
    for op in module.body.operations:
        if op.name in ("cim.acquire", "cim.release"):
            continue
        if op.name == "cim.execute":
            if execute is not None:
                return None
            execute = op
            continue
        if op.name == "func.return":
            ret = op
            continue
        return None
    if execute is None or ret is None or len(execute.results) != 2:
        return None
    if [id(v) for v in ret.operands] != [id(r) for r in execute.results]:
        return None

    body = execute.body_ops()
    names = {op.name for op in body} - {"cim.yield"}
    if names and names <= _SIM_OPS and len(body) == 2:
        sim = body[0]
        yld = body[1]
        if yld.name != "cim.yield" or \
                [id(v) for v in yld.operands] != [id(r) for r in sim.results]:
            return None
        if len(sim.operands) not in (2, 3):
            return None
        q, p = sim.operands[0], sim.operands[1]
        care = sim.operands[2] if len(sim.operands) == 3 else None
        if any(id(v) not in arg_pos for v in sim.operands):
            return None
        a = sim.attributes
        if care is not None and a["metric"] != "hamming":
            return None     # TCAM wildcards only exist for hamming search
        n, dim = p.type.shape[-2], p.type.shape[-1]
        tr = int(a.get("tile_rows", 0)) or n
        dpt = int(a.get("dims_per_tile", 0)) or dim
        gr = int(a.get("grid_rows", 0)) or -(-n // tr)
        gc = int(a.get("grid_cols", 0)) or -(-dim // dpt)
        m = 1
        for d in q.type.shape[:-1]:
            m *= d
        return SimilaritySpec(
            metric=a["metric"], k=int(a["k"]), largest=bool(a["largest"]),
            tile_rows=tr, dims_per_tile=dpt, grid_rows=gr, grid_cols=gc,
            m=m, n=n, dim=dim,
            query_arg=arg_pos[id(q)], pattern_arg=arg_pos[id(p)],
            out_v_shape=tuple(sim.results[0].type.shape),
            out_i_shape=tuple(sim.results[1].type.shape),
            care_arg=None if care is None else arg_pos[id(care)],
            in_dtypes=tuple(v.type.dtype for v in sim.operands))

    if names and names <= _TILE_OPS:
        return _spec_from_unrolled(body, arg_pos)
    return None


def _spec_from_unrolled(body, arg_pos) -> Optional[SimilaritySpec]:
    """Reconstruct the spec from explicit Fig.-5d tile ops."""
    searches = [op for op in body if op.name == "cim.search_tile"]
    topks = [op for op in body if op.name == "cim.topk_tile"]
    reshapes = [op for op in body if op.name == "cim.reshape_result"]
    yields = [op for op in body if op.name == "cim.yield"]
    if not searches or not topks or len(reshapes) != 1 or len(yields) != 1:
        return None
    fin, yld = reshapes[0], yields[0]
    if [id(v) for v in yld.operands] != [id(r) for r in fin.results]:
        return None
    first = searches[0]
    q, p = first.operands
    if id(q) not in arg_pos or id(p) not in arg_pos:
        return None
    for st in searches:
        if [id(v) for v in st.operands] != [id(q), id(p)]:
            return None
    sa = first.attributes
    metric = sa["metric"]
    phys_largest = bool(sa.get("phys_largest", False))
    largest = (not phys_largest) if metric in ("dot", "cos") else phys_largest
    gr = 1 + max(int(op.attributes["row_tile"]) for op in searches)
    gc = 1 + max(int(op.attributes["col_tile"]) for op in searches)
    if len(searches) != gr * gc or len(topks) != gr:
        return None
    n, dim = p.type.shape[-2], p.type.shape[-1]
    fa = fin.attributes
    return SimilaritySpec(
        metric=metric, k=int(fa["k"]), largest=largest,
        tile_rows=int(sa["tile_rows"]), dims_per_tile=int(sa["dims_per_tile"]),
        grid_rows=gr, grid_cols=gc, m=int(fa["m"]), n=n, dim=dim,
        query_arg=arg_pos[id(q)], pattern_arg=arg_pos[id(p)],
        out_v_shape=tuple(fin.results[0].type.shape),
        out_i_shape=tuple(fin.results[1].type.shape),
        in_dtypes=(q.type.dtype, p.type.dtype))


def extract_range_spec(module: Module) -> Optional[RangeSpec]:
    """Return the spec if ``module`` is a pure range-search program.

    Accepted shape mirrors :func:`extract_plan_spec` with a single
    ``cim.range_search`` / ``cim.tiled_range_search`` (one ``i1``
    result) in the execute body, operands fed straight from module
    arguments.  Anything else returns ``None`` — the interpreter stays
    the general path.
    """
    args = module.arguments
    arg_pos = {id(a): i for i, a in enumerate(args)}
    execute = None
    ret = None
    for op in module.body.operations:
        if op.name in ("cim.acquire", "cim.release"):
            continue
        if op.name == "cim.execute":
            if execute is not None:
                return None
            execute = op
            continue
        if op.name == "func.return":
            ret = op
            continue
        return None
    if execute is None or ret is None or len(execute.results) != 1:
        return None
    if [id(v) for v in ret.operands] != [id(r) for r in execute.results]:
        return None

    body = execute.body_ops()
    if len(body) != 2:
        return None
    rs, yld = body
    if rs.name not in _RANGE_OPS or yld.name != "cim.yield":
        return None
    if [id(v) for v in yld.operands] != [id(r) for r in rs.results]:
        return None
    if any(id(v) not in arg_pos for v in rs.operands):
        return None
    a = rs.attributes
    mode = a.get("mode", "threshold")
    if mode == "interval":
        if len(rs.operands) != 3:
            return None
        metric = "interval"
    else:
        if len(rs.operands) != 2 or "metric" not in a:
            return None
        metric = a["metric"]
    q = rs.operands[0]
    stored = rs.operands[1]
    n, dim = stored.type.shape[-2], stored.type.shape[-1]
    tr = int(a.get("tile_rows", 0)) or n
    dpt = int(a.get("dims_per_tile", 0)) or dim
    gr = int(a.get("grid_rows", 0)) or -(-n // tr)
    gc = int(a.get("grid_cols", 0)) or -(-dim // dpt)
    m = 1
    for d in q.type.shape[:-1]:
        m *= d
    return RangeSpec(
        mode=mode, metric=metric,
        threshold=float(a.get("threshold", 0.0)),
        below=bool(a.get("below", True)),
        tile_rows=tr, dims_per_tile=dpt, grid_rows=gr, grid_cols=gc,
        m=m, n=n, dim=dim,
        query_arg=arg_pos[id(q)],
        pattern_args=tuple(arg_pos[id(v)] for v in rs.operands[1:]),
        out_shape=tuple(rs.results[0].type.shape),
        in_dtypes=tuple(v.type.dtype for v in rs.operands))


def module_for_spec(spec, m: Optional[int] = None) -> Module:
    """Synthesise a ``cim`` module whose extracted spec matches ``spec``.

    Round-trips a plan spec back to IR: a single fused similarity /
    range-search op with the spec's tile geometry injected as op
    attributes (``extract_plan_spec`` / ``extract_range_spec`` read
    ``tile_rows`` / ``dims_per_tile`` off the fused op, so the
    partition pass need not run).  Module arguments are in canonical
    order — query, stored operand(s)[, care] — which is also the
    argument order of every partitioned module in this repo.

    This is what lets the hardening layer compile a *physical* plan
    (replicated/spare rows — a different ``n``) for an existing
    logical spec, and the serving layer rebuild an interpreter-
    executable module for its degraded fallback chain, without keeping
    the original module object around.

    A composite spec (anything exposing a ``flat_spec`` attribute, e.g.
    ``HierarchicalSpec``) synthesises the module of its *flat
    equivalent* — the exact search the composite approximates — which
    is precisely what the serving fallback chain and the hardening
    layer want to execute when the composite plan itself is
    unavailable.
    """
    spec = getattr(spec, "flat_spec", spec)
    from ..cim_dialect import (make_acquire, make_execute, make_range_search,
                               make_release, make_similarity, make_yield)
    from ..ir import Builder, TensorType

    m = spec.m if m is None else int(m)
    n, dim = spec.n, spec.dim
    geom = {"tile_rows": spec.tile_rows, "dims_per_tile": spec.dims_per_tile}
    is_range = isinstance(spec, RangeSpec)
    interval = is_range and spec.mode == "interval"
    n_stored = 3 if (interval or getattr(spec, "care_arg", None) is not None) \
        else 2
    arg_types = [TensorType((m, dim))] + \
        [TensorType((n, dim)) for _ in range(n_stored - 1)]
    mod = Module("spec_synth", arg_types)
    b = Builder(mod.body)
    dev = make_acquire(b)
    if is_range:
        out_types = [TensorType((m, n), "i1")]
    else:
        out_types = [TensorType((m, spec.k)), TensorType((m, spec.k), "i32")]
    exe = make_execute(b, dev.result, list(mod.arguments), out_types)
    blk = exe.region().block()
    if interval:
        q_a, lo_a, hi_a = mod.arguments
        op = make_range_search(blk, q_a, lo=lo_a, hi=hi_a, extra_attrs=geom)
    elif is_range:
        q_a, p_a = mod.arguments
        op = make_range_search(blk, q_a, patterns=p_a, metric=spec.metric,
                               threshold=spec.threshold, below=spec.below,
                               extra_attrs=geom)
    else:
        q_a, p_a = mod.arguments[0], mod.arguments[1]
        care_a = mod.arguments[2] if n_stored == 3 else None
        op = make_similarity(blk, q_a, p_a, metric=spec.metric, k=spec.k,
                             largest=spec.largest, care=care_a,
                             extra_attrs=geom)
    make_yield(blk, op.results)
    make_release(b, dev.result)
    b.ret(exe.results)
    return mod
