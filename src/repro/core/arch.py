"""Architecture specification for CAM-based accelerators (paper §II-C, §III-B).

The accelerator is a four-level hierarchy::

    system -> B banks -> T mats/bank -> A arrays/mat -> S subarrays/array
    subarray = R rows x C columns of CAM cells

Each level has an *access mode* (``parallel`` or ``sequential``).  All active
rows within a subarray are always searched in parallel; *selective row
pre-charging* (Zukowski & Wang [27]) lets a subarray hold multiple data
batches and search them over multiple cycles (the paper's ``cam-density``
mode).  The spec also carries the CAM cell type and the optimization target,
mirroring the JSON architecture-description input of Fig. 3.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional

__all__ = ["CamType", "SearchType", "Metric", "AccessMode", "ArchSpec",
           "OptimizationTarget", "PAPER_BASE_ARCH", "kazemi_arch"]


class CamType:
    BCAM = "bcam"
    TCAM = "tcam"
    MCAM = "mcam"
    ACAM = "acam"
    ALL = (BCAM, TCAM, MCAM, ACAM)


class SearchType:
    EXACT = "exact"      # EX: all cells match
    BEST = "best"        # BE: minimum-distance row(s) (winner-take-all)
    RANGE = "range"      # TH: distance below threshold
    ALL = (EXACT, BEST, RANGE)


class Metric:
    HAMMING = "hamming"
    EUCLIDEAN = "eucl"
    DOT = "dot"
    COSINE = "cos"
    ALL = (HAMMING, EUCLIDEAN, DOT, COSINE)

    @staticmethod
    def validate(name: str) -> str:
        """Reject unknown metric names at construction time.

        The engine and IR accept every member of ``ALL`` (including
        ``cos``, which the physical search runs as bipolar Hamming);
        anything else used to surface only as a deep ``ValueError``
        inside kernel dispatch.
        """
        if name not in Metric.ALL:
            raise ValueError(
                f"unknown metric {name!r}; expected one of {Metric.ALL}")
        return name


class AccessMode:
    PARALLEL = "parallel"
    SEQUENTIAL = "sequential"


class OptimizationTarget:
    LATENCY = "latency"
    POWER = "power"
    DENSITY = "density"          # array utilization via selective search
    POWER_DENSITY = "power+density"
    ALL = (LATENCY, POWER, DENSITY, POWER_DENSITY)


@dataclass(frozen=True)
class ArchSpec:
    """Static description of one CAM accelerator configuration."""

    rows: int = 32                      # R: rows per subarray
    cols: int = 32                      # C: columns per subarray
    subarrays_per_array: int = 8        # S
    arrays_per_mat: int = 4             # A
    mats_per_bank: int = 4              # T
    banks: int = 0                      # B; 0 = "as many as needed" (paper IV-B)
    cam_type: str = CamType.TCAM
    bits_per_cell: int = 1              # 1 = binary, >1 = multi-bit (MCAM)
    # access mode per level, outermost first: bank, mat, array, subarray
    access: Dict[str, str] = field(default_factory=lambda: {
        "bank": AccessMode.PARALLEL,
        "mat": AccessMode.PARALLEL,
        "array": AccessMode.PARALLEL,
        "subarray": AccessMode.PARALLEL,
    })
    # optimization knobs (paper §III-D2 "built-in optimizations")
    target: str = OptimizationTarget.LATENCY
    max_active_subarrays: int = 0       # 0 = unlimited (cam-base); 1 = cam-power
    selective_search: bool = False      # cam-density: multiple batches/array
    supports_selective: bool = True

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.cam_type not in CamType.ALL:
            raise ValueError(f"unknown cam type {self.cam_type}")
        if self.target not in OptimizationTarget.ALL:
            raise ValueError(f"unknown optimization target {self.target}")
        for lvl in ("bank", "mat", "array", "subarray"):
            if self.access.get(lvl) not in (AccessMode.PARALLEL, AccessMode.SEQUENTIAL):
                raise ValueError(f"bad access mode for {lvl}: {self.access.get(lvl)}")

    # -- capacity ------------------------------------------------------
    @property
    def subarray_cells(self) -> int:
        return self.rows * self.cols

    @property
    def subarrays_per_bank(self) -> int:
        return self.subarrays_per_array * self.arrays_per_mat * self.mats_per_bank

    @property
    def cells_per_bank(self) -> int:
        return self.subarrays_per_bank * self.subarray_cells

    def banks_needed(self, total_rows: int, total_cols: int) -> int:
        """Banks required to hold a ``total_rows x total_cols`` pattern matrix."""
        tiles = math.ceil(total_rows / self.rows) * math.ceil(total_cols / self.cols)
        per_bank = self.subarrays_per_bank
        if self.selective_search:
            # selective search stacks multiple row-batches in one subarray
            batches = max(self.rows // max(1, min(total_rows, self.rows)), 1)
            # handled more precisely by the mapper; here: capacity unchanged
        return max(1, math.ceil(tiles / per_bank))

    # -- derived convenience --------------------------------------------
    def with_target(self, target: str) -> "ArchSpec":
        """Returns a spec with optimization knobs set for ``target``."""
        if target == OptimizationTarget.LATENCY:
            return replace(self, target=target, max_active_subarrays=0,
                           selective_search=False)
        if target == OptimizationTarget.POWER:
            return replace(self, target=target, max_active_subarrays=1,
                           selective_search=False)
        if target == OptimizationTarget.DENSITY:
            return replace(self, target=target, max_active_subarrays=0,
                           selective_search=True)
        if target == OptimizationTarget.POWER_DENSITY:
            return replace(self, target=target, max_active_subarrays=1,
                           selective_search=True)
        raise ValueError(target)

    # -- (de)serialization ----------------------------------------------
    def to_json(self) -> str:
        d = {k: getattr(self, k) for k in (
            "rows", "cols", "subarrays_per_array", "arrays_per_mat",
            "mats_per_bank", "banks", "cam_type", "bits_per_cell", "target",
            "max_active_subarrays", "selective_search", "supports_selective")}
        d["access"] = dict(self.access)
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "ArchSpec":
        d = json.loads(s)
        return ArchSpec(**d)


#: The paper's validation/DSE configuration (§IV-B): 4 mats/bank, 4
#: arrays/mat, 8 subarrays/array, banks as needed.
PAPER_BASE_ARCH = ArchSpec(rows=32, cols=32, subarrays_per_array=8,
                           arrays_per_mat=4, mats_per_bank=4, banks=0)


def kazemi_arch(cols: int, rows: int = 32, bits_per_cell: int = 1) -> ArchSpec:
    """The hand-crafted HDC design of Kazemi et al. [22]: 32 x C arrays."""
    return ArchSpec(rows=rows, cols=cols, subarrays_per_array=8,
                    arrays_per_mat=4, mats_per_bank=4, banks=0,
                    cam_type=CamType.TCAM if bits_per_cell == 1 else CamType.MCAM,
                    bits_per_cell=bits_per_cell)
