"""Functional execution of C4CAM IR on the host (JAX backend).

The paper lowers ``cam`` ops to simulator calls; our simulator is JAX
itself.  Two execution paths are provided, both bit-identical in results:

* **interpreted** — walks the partitioned ``cim`` IR op-by-op (including the
  explicit Fig.-5d tile ops).  Used by tests to pin the IR semantics.
* **vectorized** — builds one jitted JAX function from the fused
  ``cim.similarity`` / ``cim.tiled_similarity`` ops using
  ``repro.kernels`` (the tiled reference path, or the Pallas kernel when
  ``backend='pallas'``).  This is the path benchmarks use.

Encoding: CAMs store cells, not floats.  For ``dot``/``cos`` on bipolar
data the search runs as Hamming distance (``dot = D - 2*h``); values are
reported back in the *metric domain* so results are comparable with the
torch reference.  ``eucl`` on ACAM/MCAM is analog-exact.

Execution engine & plan cache
-----------------------------
Neither path here is the production hot path: compiled programs dispatch
through :mod:`repro.core.engine`, which lowers a pure similarity program
into one cached, jitted ``lax.scan`` over the tile grid with query
micro-batching (see ``docs/engine.md``).  This module remains the
semantic reference the engine must match — the interpreted walk pins the
Fig.-5d tile-op semantics bit-for-bit — and the general fallback for
modules the engine cannot express.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ref as kref
from .engine import _as_2d, _encode, _metric_values
from .ir import IRError, Module, Operation, Value

__all__ = ["execute_module", "build_search_fn", "build_range_fn"]


# ---------------------------------------------------------------------------
# Host-op dispatch (the "standard MLIR pipeline" path)
# ---------------------------------------------------------------------------


def _host_eval(op: Operation, env: Dict[int, Any]) -> Sequence[Any]:
    def a(i: int):
        return env[id(op.operands[i])]

    n = op.opname
    if n == "transpose":
        x = a(0)
        d0 = op.attributes.get("dim0", -2) % x.ndim
        d1 = op.attributes.get("dim1", -1) % x.ndim
        perm = list(range(x.ndim))
        perm[d0], perm[d1] = perm[d1], perm[d0]
        return (jnp.transpose(x, perm),)
    if n in ("matmul", "mm"):
        return (a(0) @ a(1),)
    if n == "sub":
        return (a(0) - a(1),)
    if n == "add":
        return (a(0) + a(1),)
    if n == "mul":
        return (a(0) * a(1),)
    if n == "div":
        return (a(0) / a(1),)
    if n == "neg":
        return (-a(0),)
    if n == "abs":
        return (jnp.abs(a(0)),)
    if n == "norm":
        p = op.attributes.get("p", 2)
        dim = op.attributes.get("dim", -1)
        keep = op.attributes.get("keepdim", False)
        x = a(0)
        if p == 2:
            r = jnp.sqrt((x * x).sum(axis=dim, keepdims=keep))
        elif p == 1:
            r = jnp.abs(x).sum(axis=dim, keepdims=keep)
        else:
            r = (jnp.abs(x) ** p).sum(axis=dim, keepdims=keep) ** (1.0 / p)
        return (r,)
    if n == "unsqueeze":
        return (jnp.expand_dims(a(0), op.attributes["dim"]),)
    if n == "squeeze":
        return (jnp.squeeze(a(0), op.attributes["dim"]),)
    if n == "topk":
        k = int(op.attributes["k"])
        largest = bool(op.attributes.get("largest", True))
        x = a(0)
        key = x if largest else -x
        _, idx = jax.lax.top_k(key, k)
        return (jnp.take_along_axis(x, idx, axis=-1), idx.astype(jnp.int32))
    raise IRError(f"host executor: unsupported op {op.name}")


# ---------------------------------------------------------------------------
# CAM-domain helpers
# ---------------------------------------------------------------------------


# _as_2d / _metric_values / _encode are shared with the engine (the two
# paths must agree on the physical-domain translation) and live in
# repro.core.engine.


def build_search_fn(metric: str, k: int, largest: bool, *, tile_rows: int,
                    dims_per_tile: int, backend: str = "jnp"
                    ) -> Callable[..., Tuple[jax.Array, jax.Array]]:
    """Vectorized (query, patterns[, care]) -> (values, indices) CAM search.

    ``care`` (hamming only) is the per-pattern TCAM wildcard mask; the
    masked search always runs through the tiled jnp reference — it is
    the unpacked semantic oracle the engine's packed ternary path must
    match bit-for-bit.
    """
    phys_metric, to_logical, phys_largest = _metric_values(metric, largest)

    def fn(queries: jax.Array, patterns: jax.Array,
           care: Optional[jax.Array] = None):
        q2, lead = _as_2d(queries)
        qe = _encode(q2, metric)
        pe = _encode(patterns, metric)
        dim = q2.shape[-1]
        if care is not None:
            v, i = kref.cam_topk_tiled(qe, pe, metric=phys_metric, k=k,
                                       largest=phys_largest,
                                       tile_rows=tile_rows,
                                       dims_per_tile=dims_per_tile,
                                       care=care)
        elif backend == "pallas":
            from ..kernels import ops as kops
            v, i = kops.cam_topk(qe, pe, metric=phys_metric, k=k,
                                 largest=phys_largest,
                                 tile_rows=tile_rows,
                                 dims_per_tile=dims_per_tile)
        else:
            v, i = kref.cam_topk_tiled(qe, pe, metric=phys_metric, k=k,
                                       largest=phys_largest,
                                       tile_rows=tile_rows,
                                       dims_per_tile=dims_per_tile)
        v = to_logical(v, float(dim))
        out_shape = lead + (k,)
        return v.reshape(out_shape), i.reshape(out_shape)

    return fn


def build_range_fn(mode: str, *, metric: Optional[str] = None,
                   threshold: float = 0.0, below: bool = True,
                   tile_rows: int = 0, dims_per_tile: int = 0
                   ) -> Callable[..., jax.Array]:
    """Vectorized boolean range-match oracle (``cim.range_search``).

    * ``mode="interval"`` — ``fn(q, lo, hi)``: the aCAM contract of
      :func:`kref.acam_match` (pure comparisons + integer counts, so
      the result is tiling-invariant and bit-identical under any
      partition).
    * ``mode="threshold"`` — ``fn(q, p)``: encode to the physical cell
      domain, accumulate *tiled* partial distances in the same order
      the engine's scan runs (:func:`kref.tiled_distances`), convert to
      the logical metric domain, compare against the threshold.  Using
      the tiled accumulation here keeps interpreter and engine
      bit-identical for every metric, analog ones included.
    """
    if mode == "interval":
        def fn(queries, lo, hi):
            q2, lead = _as_2d(queries)
            match = kref.acam_match(q2, jnp.asarray(lo), jnp.asarray(hi))
            return match.reshape(lead + (match.shape[-1],))
        return fn

    phys_metric, to_logical, _ = _metric_values(metric, True)

    def fn(queries, patterns):
        q2, lead = _as_2d(queries)
        qe = _encode(q2, metric)
        pe = _encode(jnp.asarray(patterns), metric)
        dim = q2.shape[-1]
        tr = tile_rows or pe.shape[0]
        dpt = dims_per_tile or dim
        d = kref.tiled_distances(qe, pe, metric=phys_metric, tile_rows=tr,
                                 dims_per_tile=dpt)
        v = to_logical(d, float(dim))
        match = (v <= threshold) if below else (v >= threshold)
        return match.reshape(lead + (match.shape[-1],))

    return fn


# ---------------------------------------------------------------------------
# IR interpreter
# ---------------------------------------------------------------------------


def execute_module(module: Module, *inputs, backend: str = "jnp"
                   ) -> Tuple[Any, ...]:
    """Interpret a torch/cim-level module with JAX semantics."""
    env: Dict[int, Any] = {}
    for arg, val in zip(module.arguments, inputs):
        env[id(arg)] = jnp.asarray(val)

    def run_block(ops: List[Operation]) -> None:
        for op in ops:
            if op.name == "func.return":
                continue
            results = eval_op(op)
            for r, v in zip(op.results, results):
                env[id(r)] = v

    def eval_op(op: Operation) -> Sequence[Any]:
        nm = op.name
        if nm == "cim.acquire":
            return (object(),)
        if nm == "cim.release":
            return ()
        if nm == "cim.execute":
            yielded: List[Any] = []
            for inner in op.body_ops():
                if inner.name == "cim.yield":
                    yielded = [env[id(v)] for v in inner.operands]
                    continue
                rs = eval_op(inner)
                for r, v in zip(inner.results, rs):
                    env[id(r)] = v
            return tuple(yielded)
        if nm == "cim.similarity" or nm == "cim.tiled_similarity":
            metric = op.attributes["metric"]
            k = int(op.attributes["k"])
            largest = bool(op.attributes["largest"])
            tr = int(op.attributes.get("tile_rows", 0)) or None
            dpt = int(op.attributes.get("dims_per_tile", 0)) or None
            q = env[id(op.operands[0])]
            p = env[id(op.operands[1])]
            care = env[id(op.operands[2])] if len(op.operands) == 3 else None
            if tr is None:   # unpartitioned: whole-array search
                n, dim = p.shape[-2], p.shape[-1]
                tr, dpt = n, dim
            fn = build_search_fn(metric, k, largest, tile_rows=tr,
                                 dims_per_tile=dpt, backend=backend)
            v, i = fn(q, p, care)
            # match declared result shapes (e.g. (k,) for 1-D queries)
            v = v.reshape(op.results[0].type.shape)
            i = i.reshape(op.results[1].type.shape)
            return (v, i)
        if nm == "cim.range_search" or nm == "cim.tiled_range_search":
            mode = op.attributes.get("mode", "threshold")
            tr = int(op.attributes.get("tile_rows", 0))
            dpt = int(op.attributes.get("dims_per_tile", 0))
            fn = build_range_fn(
                mode, metric=op.attributes.get("metric"),
                threshold=float(op.attributes.get("threshold", 0.0)),
                below=bool(op.attributes.get("below", True)),
                tile_rows=tr, dims_per_tile=dpt)
            args = [env[id(v)] for v in op.operands]
            match = fn(*args)
            out_shape = op.results[0].type.shape
            want = 1
            for d in out_shape:
                want *= d
            if match.size == want:   # runtime M may differ from the trace
                match = match.reshape(out_shape)
            return (match,)
        if nm == "cim.search_tile":
            q = env[id(op.operands[0])]
            p = env[id(op.operands[1])]
            metric = op.attributes["metric"]
            phys_largest = bool(op.attributes.get("phys_largest", False))
            phys_metric, _, _ = _metric_values(metric, True)
            q2, _ = _as_2d(q)
            qe, pe = _encode(q2, metric), _encode(p, metric)
            r = int(op.attributes["row_tile"]); c = int(op.attributes["col_tile"])
            tr = int(op.attributes["tile_rows"]); dpt = int(op.attributes["dims_per_tile"])
            rows = pe[r * tr: (r + 1) * tr, c * dpt: (c + 1) * dpt]
            qs = qe[:, c * dpt: (c + 1) * dpt]
            d = kref.distances(qs, rows, phys_metric)
            # pad missing rows with the losing value so they never win
            if d.shape[1] < tr:
                lose = -jnp.inf if phys_largest else jnp.inf
                d = jnp.pad(d, ((0, 0), (0, tr - d.shape[1])),
                            constant_values=lose)
            return (d,)
        if nm == "cim.merge_partial":
            if op.attributes["dir"] == "horizontal":
                a0 = env[id(op.operands[0])]
                a1 = env[id(op.operands[1])]
                # +-inf padding absorbs finite partial sums
                return (a0 + a1,)
            largest = bool(op.attributes.get("largest", False))
            va, ia, vb, ib = (env[id(v)] for v in op.operands)
            k = va.shape[-1]
            return kref.merge_topk(va, ia, vb, ib, k=k, largest=largest)
        if nm == "cim.topk_tile":
            d = env[id(op.operands[0])]
            k = int(op.attributes["k"])
            largest = bool(op.attributes["largest"])
            tr = int(op.attributes["tile_rows"])
            roff = int(op.attributes["row_tile"]) * tr
            kk = min(k, d.shape[-1])
            key = d if largest else -d
            _, idx = jax.lax.top_k(key, kk)
            vals = jnp.take_along_axis(d, idx, axis=-1)
            idx = idx.astype(jnp.int32) + roff
            if kk < k:
                vals = jnp.pad(vals, ((0, 0), (0, k - kk)),
                               constant_values=-jnp.inf if largest else jnp.inf)
                idx = jnp.pad(idx, ((0, 0), (0, k - kk)),
                              constant_values=2 ** 30)
            return (vals, idx)
        if nm == "cim.reshape_result":
            v = env[id(op.operands[0])]
            i = env[id(op.operands[1])]
            metric = op.attributes.get("metric")
            if metric in ("dot", "cos"):
                # convert physical Hamming counts back to the logical metric
                v = float(op.attributes["dim"]) - 2.0 * v
            vt = op.results[0].type
            return (v.reshape(vt.shape), i.reshape(op.results[1].type.shape))
        if op.dialect in ("torch", "cim"):
            return _host_eval(op, env)
        raise IRError(f"executor: unsupported op {op.name}")

    run_block(module.body.operations)
    return tuple(env[id(v)] for v in module.return_values())
