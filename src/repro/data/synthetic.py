"""Deterministic synthetic datasets (see package docstring)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["TokenStream", "hdc_dataset", "hdc_mnist_dataset", "knn_dataset"]


def _rng(seed: int, *stream: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, *stream]))


@dataclass
class TokenStream:
    """Deterministic packed LM batches.

    Documents are sampled with a Zipfian unigram model plus injected
    copy/repeat structure (so a model can actually reduce loss), packed
    back-to-back into ``seq_len``-token rows with EOS=0 separators.
    ``batch(i)`` is a pure function of ``(seed, i)``.
    """

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    mean_doc_len: int = 512

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        n = max(8, int(rng.exponential(self.mean_doc_len)))
        # zipf-ish unigram over the vocab
        base = (rng.pareto(1.2, size=n) * 7).astype(np.int64) % (self.vocab - 1)
        tok = base + 1                       # 0 is EOS
        # repeat structure: copy a prefix window somewhere later
        if n > 32:
            w = int(rng.integers(8, 17))
            src = int(rng.integers(0, n - 2 * w))
            dst = int(rng.integers(src + w, n - w))
            tok[dst:dst + w] = tok[src:src + w]
        return tok

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = _rng(self.seed, index)
        rows = np.zeros((self.global_batch, self.seq_len), np.int32)
        mask = np.ones((self.global_batch, self.seq_len), np.float32)
        for b in range(self.global_batch):
            buf: list = []
            while len(buf) < self.seq_len:
                buf.extend(self._doc(rng).tolist())
                buf.append(0)                # EOS
            rows[b] = np.asarray(buf[: self.seq_len], np.int32)
        return {"tokens": rows, "mask": mask}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def hdc_dataset(n_classes: int = 10, dim: int = 8192, n_queries: int = 10000,
                seed: int = 7, noise: float = 0.15,
                binary: bool = True) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """HDC class hypervectors + noisy queries (the paper's MNIST/8k stand-in).

    Returns (class_hvs (C, D), queries (Q, D), labels (Q,)).  Queries are
    class vectors with ``noise`` fraction of dimensions flipped — the
    associative-memory recall workload of Kazemi et al. [22].
    """
    rng = _rng(seed, 0)
    classes = rng.integers(0, 2, size=(n_classes, dim)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n_queries)
    flips = rng.random((n_queries, dim)) < noise
    queries = classes[labels].copy()
    queries[flips] = 1.0 - queries[flips]
    if not binary:                       # multi-bit (MCAM) variant
        classes = classes * 14 + rng.integers(0, 2, classes.shape)
        queries = queries * 14 + rng.integers(0, 2, queries.shape)
    return classes, queries, labels


def hdc_mnist_dataset(n_train: int = 512, n_test: int = 256,
                      n_classes: int = 10, side: int = 14, seed: int = 3,
                      noise: float = 0.3, overlap: float = 0.55
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 np.ndarray, np.ndarray]:
    """MNIST-shaped *feature* samples for the end-to-end HDC pipeline.

    Unlike :func:`hdc_dataset` (which hands out ready-made class
    hypervectors — the associative-memory *recall* workload), this
    returns raw ``side x side`` images in ``[0, 1]`` that must be
    **encoded** into hypervectors: each class owns a blob template
    drawn as ``overlap`` parts shared background + ``(1 - overlap)``
    class-specific structure, and samples add pixel noise.  The overlap
    makes classes confusable enough that one-shot HDC training lands
    mid-range and perceptron retraining visibly improves it — the
    regime Figs. 8/9 retrain in.

    Returns ``(train_x (n_train, side*side), train_y, test_x, test_y)``.
    """
    rng = _rng(seed, 2)
    dim = side * side
    background = rng.random(dim).astype(np.float32)
    templates = (overlap * background[None, :]
                 + (1 - overlap) * rng.random((n_classes, dim))
                 ).astype(np.float32)

    def draw(n: int) -> Tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        x = templates[y] + rng.normal(0.0, noise, (n, dim)).astype(np.float32)
        return np.clip(x, 0.0, 1.0).astype(np.float32), y

    train_x, train_y = draw(n_train)
    test_x, test_y = draw(n_test)
    return train_x, train_y, test_x, test_y


def knn_dataset(n_gallery: int = 180_000, dim: int = 1024,
                n_queries: int = 624, n_classes: int = 2,
                seed: int = 11) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """KNN gallery/query features (the Pneumonia X-ray stand-in).

    Class-conditional Gaussians in feature space; returns
    (gallery (N, D), g_labels, queries (Q, D), q_labels)."""
    rng = _rng(seed, 1)
    centers = rng.standard_normal((n_classes, dim)).astype(np.float32) * 2.0
    g_labels = rng.integers(0, n_classes, size=n_gallery)
    gallery = centers[g_labels] + rng.standard_normal(
        (n_gallery, dim)).astype(np.float32)
    q_labels = rng.integers(0, n_classes, size=n_queries)
    queries = centers[q_labels] + rng.standard_normal(
        (n_queries, dim)).astype(np.float32)
    return gallery, g_labels.astype(np.int32), queries, q_labels.astype(np.int32)
