"""Data pipeline: deterministic synthetic corpora + packing + host sharding.

No datasets ship with this container (DESIGN.md deviations register), so
the pipeline generates deterministic synthetic data with matched shapes:

* ``TokenStream`` — hash-based token sequences (same (seed, index) ->
  same document on every host), document packing into fixed-length
  training sequences with EOS separators and loss masks.
* ``hdc_dataset`` / ``knn_dataset`` — the paper's two benchmark workloads
  (HDC hypervectors, KNN feature gallery) with class structure so accuracy
  is meaningful (CAM result must equal the dense-reference result).
* ``ShardedLoader`` — per-host slicing by (process_index, process_count)
  and device placement; batches are globally deterministic so elastic
  restarts resume the stream exactly (the loader state is one integer).
"""

from .synthetic import TokenStream, hdc_dataset, hdc_mnist_dataset, knn_dataset
from .loader import ShardedLoader

__all__ = ["TokenStream", "hdc_dataset", "hdc_mnist_dataset", "knn_dataset",
           "ShardedLoader"]
