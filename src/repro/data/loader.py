"""Host-sharded, device-placing loader with O(1) resumable state."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

__all__ = ["ShardedLoader"]


@dataclass
class ShardedLoader:
    """Wraps a ``batch(i) -> dict`` source (e.g. TokenStream).

    * slices each global batch by host (``process_index``/``process_count``)
      so every host materializes only its shard,
    * optionally places batches with a NamedSharding (single-controller
      multi-host pattern: ``jax.make_array_from_process_local_data``),
    * state is the integer ``step`` — checkpointable and elastic-safe
      (batch content is a pure function of (seed, step), independent of the
      host count at restore time).
    """

    source: Any
    sharding: Optional[Any] = None       # NamedSharding for the batch dims
    step: int = 0

    def host_slice(self, arr: np.ndarray) -> np.ndarray:
        n_proc = jax.process_count()
        if n_proc == 1:
            return arr
        b = arr.shape[0]
        per = b // n_proc
        i = jax.process_index()
        return arr[i * per:(i + 1) * per]

    def next(self) -> Dict[str, Any]:
        batch = self.source.batch(self.step)
        self.step += 1
        out = {}
        for k, v in batch.items():
            local = self.host_slice(v)
            if self.sharding is not None:
                try:
                    out[k] = jax.make_array_from_process_local_data(
                        self.sharding, local)
                    continue
                except Exception:
                    pass
            out[k] = local
        return out

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        while True:
            yield self.next()

    # -- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, d: Dict[str, int]) -> None:
        self.step = int(d["step"])
