"""AdamW with fp32 master weights, built directly on pytrees.

State layout (one leaf per parameter leaf, same tree structure — so any
parameter sharding spec lifts to the optimizer state by construction):

* ``mu`` / ``nu``: fp32 first/second moments,
* ``master``: fp32 master copy of the parameters (params themselves may be
  bf16; updates are computed in fp32 and cast back),
* ``count``: int32 step counter (replicated scalar).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # leaves whose path contains one of these substrings skip weight decay
    no_decay_keys: Tuple[str, ...] = ("scale", "bias", "norm", "A_log", "D",
                                      "dt_bias")
    # distributed-memory knobs (§Perf): Adafactor-style factored second
    # moment for >=2-D leaves (O(rows+cols) instead of O(rows*cols)) and a
    # reduced-precision first moment.  The fp32 master copy is unaffected.
    factored_nu: bool = False
    mu_dtype: str = "float32"


class OptState(NamedTuple):
    mu: Any
    nu: Any
    master: Any
    count: jax.Array


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _is_factored(p, cfg: AdamWConfig) -> bool:
    return cfg.factored_nu and p.ndim >= 2


def _nu_init(p, cfg: AdamWConfig):
    if _is_factored(p, cfg):
        return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
    return jnp.zeros_like(p, dtype=jnp.float32)


def adamw_init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    mu_dt = jnp.dtype(cfg.mu_dtype)
    return OptState(
        mu=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dt), params),
        nu=jax.tree.map(lambda p: _nu_init(p, cfg), params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
        count=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(grads: Any, state: OptState, params: Any, lr: jax.Array,
                 cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(
        lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                      + (1 - cfg.b1) * g).astype(m.dtype), state.mu, grads)

    def nu_update(v, g):
        if isinstance(v, dict):                    # factored (Adafactor)
            g2 = g * g + 1e-30
            return {"vr": cfg.b2 * v["vr"] + (1 - cfg.b2) * g2.mean(-1),
                    "vc": cfg.b2 * v["vc"] + (1 - cfg.b2) * g2.mean(-2)}
        return cfg.b2 * v + (1 - cfg.b2) * g * g

    nu = jax.tree.map(nu_update, state.nu, grads,
                      is_leaf=lambda x: isinstance(x, dict) and "vr" in x)

    def denom(v):
        if isinstance(v, dict):
            vr, vc = v["vr"] / c2, v["vc"] / c2
            vhat = (vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
                    )[..., None] * vc[..., None, :]
            return jnp.sqrt(vhat) + cfg.eps
        return jnp.sqrt(v / c2) + cfg.eps

    # per-leaf weight-decay mask from path names
    paths = [
        _path_str(p) for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]
    ]
    flat_m, treedef = jax.tree_util.tree_flatten(mu)
    flat_v = jax.tree.leaves(
        nu, is_leaf=lambda x: isinstance(x, dict) and "vr" in x)
    flat_w = jax.tree.leaves(state.master)

    new_master = []
    for path, m, v, w in zip(paths, flat_m, flat_v, flat_w):
        upd = (m.astype(jnp.float32) / c1) / denom(v)
        if cfg.weight_decay and not any(k in path for k in cfg.no_decay_keys):
            upd = upd + cfg.weight_decay * w
        new_master.append(w - lr * upd)
    master = jax.tree_util.tree_unflatten(treedef, new_master)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(mu, nu, master, count), metrics
