"""Sharded optimizer substrate (no external deps — optax is not available).

AdamW with decoupled weight decay, global-norm clipping, and
warmup+cosine/linear schedules.  Optimizer state mirrors the parameter
pytree, so the ZeRO-3 sharding of the parameters applies verbatim to the
moments and the fp32 master copy.

Also hosts the distributed-optimization knobs used by the train step:

* ``GradientCompression`` — error-feedback int8 / top-k compressors applied
  to data-parallel gradient all-reduces (see `repro.distributed.compression`).
"""

from .adamw import AdamWConfig, OptState, adamw_init, adamw_update, clip_by_global_norm
from .schedule import Schedule, warmup_cosine, warmup_linear, constant

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "Schedule", "warmup_cosine",
           "warmup_linear", "constant"]
