"""Learning-rate schedules as pure step -> lr callables."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]

__all__ = ["Schedule", "warmup_cosine", "warmup_linear", "constant"]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_linear(lr: float, warmup: int, total: int,
                  floor: float = 0.0) -> Schedule:
    def fn(step):
        s = step.astype(jnp.float32) + 1.0
        warm = s / jnp.maximum(warmup, 1)
        decay = 1.0 - (s - warmup) / jnp.maximum(total - warmup, 1)
        return lr * jnp.clip(jnp.minimum(warm, decay), floor / lr, 1.0)
    return fn


def warmup_cosine(lr: float, warmup: int, total: int,
                  floor_frac: float = 0.1) -> Schedule:
    def fn(step):
        s = step.astype(jnp.float32) + 1.0
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * jnp.where(s < warmup, warm, cos)
    return fn
