"""Plan autotuning + persistent plan store (ROADMAP item 3).

Two pieces, usable separately but designed together:

* :mod:`.tuner` — :func:`tune_plan`: greedy coordinate-descent search
  over the engine's plan knobs (tile geometry, micro-batch, packing,
  scan unroll, shard count), measuring real executions and keeping the
  fastest *verified* candidate.
* :mod:`.store` — :class:`PlanStore`: a directory (``REPRO_PLAN_STORE``)
  persisting winning configs and AOT-serialized executables, so a fresh
  process skips both the search and the XLA compile.

Typical flows::

    from repro.tune import tune_plan
    res = tune_plan(module, queries, gallery)   # searches, maybe persists
    res.plan.execute(queries, gallery)

    # cold start in a later process (REPRO_PLAN_STORE set):
    res = tune_plan(module, queries, gallery)   # res.trials == 0
"""

from .store import (PlanStore, active_store, plan_store_stats,
                    reset_plan_store_stats)
from .tuner import (TuneResult, plan_for_config, reset_tune_stats, tune_plan,
                    tune_stats, warm_start_plan)

__all__ = [
    "PlanStore", "active_store", "plan_store_stats",
    "reset_plan_store_stats",
    "TuneResult", "plan_for_config", "tune_plan", "tune_stats",
    "reset_tune_stats", "warm_start_plan",
]
