"""Persistent on-disk plan store: tuned configs + AOT-serialized executables.

The engine's process-wide plan cache (``repro.core.engine.cache``) dies
with the process, so every fresh server pays autotuning *and* XLA
compilation again — cold-start elimination is ROADMAP item 3.  This
module persists both halves under a directory named by
``REPRO_PLAN_STORE``:

* **config records** (``cfg-<backend>-<workload>.json``) — the winning
  knob settings the autotuner found for a workload (tile geometry,
  micro-batch, pack, unroll, shards), keyed by
  :func:`~repro.core.engine.spec.workload_digest` (the spec with its
  tile geometry normalised away: the tuner searches over geometry, so
  the key must not depend on it).  Loading a config skips the search.

* **AOT executables** (``aot-<key>.pkl``) — the winning plan's jitted
  ``prepare`` / ``chunk_fn`` pair, lowered at concrete shapes, compiled
  once, and serialized via ``jax.experimental.serialize_executable``.
  Loading one skips XLA compilation entirely: the adopted callables run
  the deserialized PjRt executable and only fall back to the plan's
  original (lazily-jitted) functions on an input shape/dtype mismatch.
  The key covers the exact spec digest, batch/pack/unroll, the
  jax/jaxlib versions and the device platform — an executable compiled
  by a different toolchain or for different hardware is invisible, not
  wrong.  Serialization failures (a jaxlib that refuses, an unpicklable
  closure) degrade to config-only persistence, never to an error.

Eligibility for the AOT half is deliberately narrow: single-device jnp
non-tiny plans.  Tiny plans are shape-polymorphic (their executables
trace at the caller's query count), sharded plans bake in a device
topology, and pallas kernels carry their own compilation pipeline.

Every load/save is counted process-wide (:func:`plan_store_stats`) so
tests and benchmarks can pin "zero XLA compiles" as ``exec_hits == 2``
with ``exec_fallbacks == 0`` — if the adopted pair never falls back,
the python-jitted originals are never invoked and nothing compiles.
"""

from __future__ import annotations

import json
import os
import pickle
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax

from ..core.engine.base import PlanBase
from ..core.engine.spec import spec_digest, workload_digest
from ..core.envcfg import env_path
from ..obs.trace import instant, tracer

__all__ = ["PlanStore", "active_store", "plan_store_stats",
           "reset_plan_store_stats"]

_LOCK = threading.Lock()
_STORES: Dict[str, "PlanStore"] = {}
_STATS = {"config_hits": 0, "config_misses": 0, "config_saves": 0,
          "exec_hits": 0, "exec_misses": 0, "exec_saves": 0,
          "exec_fallbacks": 0, "exec_skips": 0}


def plan_store_stats() -> Dict[str, int]:
    """Process-wide store counters (hits/misses/saves/fallbacks)."""
    with _LOCK:
        return dict(_STATS)


def reset_plan_store_stats() -> None:
    with _LOCK:
        for k in _STATS:
            _STATS[k] = 0


def _bump(key: str, n: int = 1) -> None:
    with _LOCK:
        _STATS[key] += n


def active_store() -> Optional["PlanStore"]:
    """The store named by ``REPRO_PLAN_STORE``, or ``None`` when unset.

    A blank value raises (shell quoting accident, see ``envcfg``); a
    set value creates the directory on first use.  One :class:`PlanStore`
    instance is shared per resolved path.
    """
    path = env_path("REPRO_PLAN_STORE")
    if path is None:
        return None
    path = os.path.abspath(path)
    with _LOCK:
        store = _STORES.get(path)
        if store is None:
            store = _STORES[path] = PlanStore(path)
        return store


def _atomic_write(path: str, data: bytes) -> None:
    """Write-then-rename so a concurrent reader never sees a torn file
    (two processes racing on the same store is the normal warm-start
    topology: a tuner writing while servers read)."""
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                               prefix=".tmp-", suffix=".part")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _toolchain_tag() -> str:
    """The environment half of the AOT key: a serialized executable is
    only valid for the exact compiler + runtime + device that built it."""
    import jaxlib
    dev = jax.devices()[0]
    return f"{jax.__version__}|{jaxlib.__version__}|{dev.platform}|" \
           f"{dev.device_kind}"


def _leaf_sig(args: Tuple[Any, ...]):
    return [(tuple(x.shape), str(x.dtype))
            for x in jax.tree_util.tree_leaves(args)]


class PlanStore:
    """One on-disk plan store directory (see module docstring)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # -- tuned-config records ---------------------------------------------

    def _config_path(self, spec, backend: str) -> str:
        return os.path.join(
            self.root, f"cfg-{backend}-{workload_digest(spec)}.json")

    def load_config(self, spec, backend: str) -> Optional[Dict[str, Any]]:
        """The tuned config for this workload + backend, or ``None``."""
        path = self._config_path(spec, backend)
        try:
            with open(path) as f:
                cfg = json.load(f)
        except (OSError, ValueError):
            _bump("config_misses")
            if tracer.enabled:
                instant("store.config_miss", pid="engine",
                        args={"backend": backend})
            return None
        _bump("config_hits")
        if tracer.enabled:
            instant("store.config_hit", pid="engine",
                    args={"backend": backend,
                          "speedup": cfg.get("speedup")})
        return cfg

    def save_config(self, spec, backend: str,
                    config: Dict[str, Any]) -> str:
        path = self._config_path(spec, backend)
        rec = dict(config)
        rec.setdefault("version", 1)
        rec["workload"] = workload_digest(spec)
        _atomic_write(path, json.dumps(rec, indent=1,
                                       sort_keys=True).encode())
        _bump("config_saves")
        return path

    # -- AOT-serialized executables ---------------------------------------

    @staticmethod
    def _exec_eligible(plan: PlanBase) -> bool:
        return (plan.backend == "jnp" and plan.shards == 1
                and not plan.tiny)

    def _exec_path(self, plan: PlanBase) -> str:
        import hashlib
        key = "|".join([spec_digest(plan.spec), plan.backend,
                        str(plan.batch), str(int(plan.packed)),
                        str(plan.unroll), _toolchain_tag()])
        return os.path.join(
            self.root,
            f"aot-{hashlib.sha256(key.encode()).hexdigest()[:40]}.pkl")

    def persist_executables(self, plan: PlanBase,
                            stored: Tuple[Any, ...]) -> bool:
        """AOT-compile + serialize the plan's prepare/chunk pair.

        ``stored`` are concrete stored-operand arrays (the tuned
        gallery, or ``(gallery, care)`` / ``(lo, hi)``) — they fix the
        avals the executables are lowered at; serving processes that
        pass differently-shaped operands simply fall back to lazy jit.
        Returns ``False`` (config-only persistence) on ineligible plans
        or any serialization refusal, never raises.
        """
        if not self._exec_eligible(plan):
            _bump("exec_skips")
            return False
        try:
            import jax.numpy as jnp
            from jax.experimental import serialize_executable as se

            srcs = tuple(jnp.asarray(s) for s in stored)
            src_sds = tuple(jax.ShapeDtypeStruct(s.shape, s.dtype)
                            for s in srcs)
            prepared_sds = jax.eval_shape(plan._prepare, *src_sds)
            q_sds = jax.ShapeDtypeStruct((plan.batch, plan.spec.dim),
                                         jnp.float32)

            def pack(jitted, *args):
                compiled = jitted.lower(*args).compile()
                payload, in_tree, out_tree = se.serialize(compiled)
                return {"payload": payload, "in_tree": in_tree,
                        "out_tree": out_tree, "in_leaves": _leaf_sig(args)}

            blob = pickle.dumps({
                "version": 1,
                "toolchain": _toolchain_tag(),
                "prepare": pack(plan._prepare, *src_sds),
                "chunk": pack(plan._chunk_fn, q_sds, prepared_sds),
            })
        except Exception:
            # config-only fallback: the tuned knobs still persist, only
            # the compile skip is lost (e.g. a jaxlib without
            # serialize support, or an executable it refuses to pickle)
            _bump("exec_skips")
            return False
        _atomic_write(self._exec_path(plan), blob)
        _bump("exec_saves")
        return True

    def adopt_executables(self, plan: PlanBase) -> bool:
        """Swap ``plan``'s jitted prepare/chunk for stored AOT ones.

        Called by ``get_plan`` on every freshly built eligible plan.
        The adopted callables check the flattened input shapes/dtypes
        against the serialized avals and fall back to the original
        (lazily-jitted) function on mismatch — counted, so a warm-start
        test asserting ``exec_fallbacks == 0`` has proven the python
        jit was never entered.
        """
        if not self._exec_eligible(plan):
            return False
        path = self._exec_path(plan)
        try:
            with open(path, "rb") as f:
                rec = pickle.loads(f.read())
            from jax.experimental import serialize_executable as se
            loaded = {}
            for name in ("prepare", "chunk"):
                r = rec[name]
                loaded[name] = (se.deserialize_and_load(
                    r["payload"], r["in_tree"], r["out_tree"]),
                    r["in_leaves"])
        except Exception:
            _bump("exec_misses")
            if tracer.enabled:
                instant("store.exec_miss", pid="engine")
            return False

        def wrap(compiled, expect, fallback):
            def call(*args):
                if _leaf_sig(args) != expect:
                    _bump("exec_fallbacks")
                    return fallback(*args)
                return compiled(*args)
            return call

        plan._prepare = wrap(*loaded["prepare"], plan._prepare)
        plan._chunk_fn = wrap(*loaded["chunk"], plan._chunk_fn)
        # one hit per adopted executable: a warm process serving one
        # plan reads exactly exec_hits == 2 (prepare + chunk)
        _bump("exec_hits", 2)
        if tracer.enabled:
            instant("store.exec_adopted", pid="engine",
                    args={"batch": plan.batch, "packed": plan.packed,
                          "unroll": plan.unroll})
        return True
