"""Search-based plan autotuner: measure candidates, keep the best.

The engine picks tile geometry, micro-batch, packing, unroll and shard
count by fixed heuristics (the arch's subarray shape, power-of-two
batch rounding, auto-pack).  The DSE benches (``BENCH_fig8_dse``,
``BENCH_fig9_isocapacity``) show the space matters; this module
searches it *empirically*, using the existing plan machinery as the
measurement harness — the exemplar shape is candidate generation →
measure → keep best (the NAS repo named in ROADMAP item 3).

:func:`tune_plan` runs greedy coordinate descent over the knob axes:
each axis is swept holding the others at the current best, and a
candidate only replaces the incumbent when it is both *faster* and
*verified* against the baseline plan's output (bit-exact for the
integer metrics, tolerance for the float ones — a tuned plan that
returns different answers is not a tuned plan).  Every trial is an
ordinary ``get_plan`` build + warm + timed executes, traced as
``tune.trial`` spans, and bounded by ``REPRO_TUNE_TRIALS`` /
``REPRO_TUNE_BUDGET_S``.

With a persistent store configured (``REPRO_PLAN_STORE``), the winning
config is saved and the winning plan's executables are AOT-serialized
(:meth:`~.store.PlanStore.persist_executables`); a later
:func:`tune_plan` for the same workload returns from the store with
**zero trials**, and :func:`warm_start_plan` gives the serving layer
the same skip at server construction.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.engine import (PlanBase, RangePlan, RangeSpec, SearchPlan,
                           extract_plan_spec, extract_range_spec, get_plan,
                           module_for_spec)
from ..core.engine.spec import _PACKABLE_METRICS
from ..core.envcfg import env_float, env_int
from ..obs.trace import instant, trace_span, tracer
from .store import active_store

__all__ = ["TuneResult", "tune_plan", "warm_start_plan", "tune_stats",
           "reset_tune_stats"]

import threading

_LOCK = threading.Lock()
_STATS = {"tunes": 0, "trials": 0, "store_hits": 0, "rejected": 0}


def tune_stats() -> Dict[str, int]:
    """Process-wide tuner counters: completed tunes, measured trials,
    store short-circuits, and correctness-rejected candidates."""
    with _LOCK:
        return dict(_STATS)


def reset_tune_stats() -> None:
    with _LOCK:
        for k in _STATS:
            _STATS[k] = 0


def _bump(key: str, n: int = 1) -> None:
    with _LOCK:
        _STATS[key] += n


@dataclass
class TuneResult:
    """Outcome of one :func:`tune_plan` call."""

    plan: PlanBase
    config: Dict[str, Any]
    trials: int
    from_store: bool
    base_s: float = 0.0
    best_s: float = 0.0
    history: List[Dict[str, Any]] = field(default_factory=list, repr=False)

    @property
    def speedup(self) -> float:
        return self.base_s / self.best_s if self.best_s > 0 else 1.0


def _tuned_spec(spec, tile_rows: int, dims_per_tile: int):
    """The spec re-tiled at a candidate geometry (grids re-derived)."""
    tr = max(1, min(int(tile_rows), spec.n))
    dpt = max(1, min(int(dims_per_tile), spec.dim))
    return replace(spec, tile_rows=tr, dims_per_tile=dpt,
                   grid_rows=-(-spec.n // tr), grid_cols=-(-spec.dim // dpt))


def plan_for_config(spec, cfg: Dict[str, Any]) -> Optional[PlanBase]:
    """Build (or cache-hit) the plan a config record describes."""
    tuned = _tuned_spec(spec, cfg["tile_rows"], cfg["dims_per_tile"])
    shards = int(cfg.get("shards") or 1)
    return get_plan(module_for_spec(tuned), backend=cfg["backend"],
                    batch=int(cfg["batch"]),
                    shards=None if shards <= 1 else shards,
                    pack=cfg.get("pack"), unroll=int(cfg.get("unroll", 1)))


def _config_of(plan: PlanBase, backend: str) -> Dict[str, Any]:
    return {"backend": backend, "tile_rows": plan.spec.tile_rows,
            "dims_per_tile": plan.spec.dims_per_tile,
            "batch": plan.batch, "pack": plan.packed,
            "unroll": plan.unroll, "shards": plan.shards}


def _ordered_inputs(spec, inputs: Tuple[Any, ...]) -> Tuple[Any, ...]:
    """Re-wire caller inputs (original module argument order) into the
    canonical order of ``module_for_spec`` modules: query first, stored
    operands after — so one input tuple drives both the baseline plan
    and every re-tiled candidate."""
    if isinstance(spec, RangeSpec):
        pos = (spec.query_arg,) + tuple(spec.pattern_args)
    else:
        pos = (spec.query_arg, spec.pattern_arg)
        if spec.care_arg is not None:
            pos += (spec.care_arg,)
    return tuple(inputs[p] for p in pos)


def _canonical_spec(spec):
    """The spec as ``module_for_spec`` round-trips it (canonical
    argument wiring) — every candidate, including the baseline, is
    built through this so measurements compare geometry, not wiring."""
    mod = module_for_spec(spec)
    out = extract_plan_spec(mod)
    if out is None:
        out = extract_range_spec(mod)
    return out


def _verify(spec, base_out, out) -> bool:
    """Candidate output matches the baseline plan's output.

    Integer-count metrics (hamming / dot / interval violations, packed
    or not) are bit-exact by the engine's numerical contract, and the
    tournament's stable merges make top-k indices deterministic across
    tile geometry.  Float accumulations (eucl, cos values) reorder
    across ``dims_per_tile``, so values are compared at tolerance and
    near-tie index flips are not grounds for rejection.
    """
    exact = spec.metric in ("hamming", "dot", "interval")
    if isinstance(spec, RangeSpec):
        a, b = np.asarray(base_out), np.asarray(out)
        return bool((a == b).all()) if exact else \
            float((a != b).mean()) < 1e-3
    bv, bi = (np.asarray(x) for x in base_out)
    cv, ci = (np.asarray(x) for x in out)
    if exact:
        return bool((bv == cv).all() and (bi == ci).all())
    return bool(np.allclose(bv, cv, rtol=1e-4, atol=1e-4))


def _measure(plan: PlanBase, inputs: Tuple[Any, ...], reps: int):
    """Median wall-clock of ``reps`` synchronous executes (after one
    warm-up execute that absorbs compile + pattern prep)."""
    out = jax.block_until_ready(plan.execute(*inputs))
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.execute(*inputs))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def _axis_values(spec, backend: str, m: int) -> List[Tuple[str, List[Any]]]:
    """The coordinate-descent axes, clamped to the workload."""
    n, dim = spec.n, spec.dim
    tile_rows = sorted({min(t, n) for t in (16, 32, 64, 128, 256, 512)})
    dpts = sorted({min(d, dim) for d in (32, 64, 128, 256)})
    batches = sorted({min(b, max(8, 2 * m)) for b in (16, 32, 64, 128, 256)})
    axes: List[Tuple[str, List[Any]]] = [
        ("tile_rows", tile_rows),
        ("dims_per_tile", dpts),
        ("batch", batches),
        ("unroll", [1, 2, 4] if backend == "jnp" else [1]),
    ]
    if spec.metric in _PACKABLE_METRICS and \
            getattr(spec, "mode", "threshold") != "interval":
        axes.append(("pack", [True, False]))
    if backend == "jnp" and jax.device_count() > 1:
        axes.append(("shards", [1, jax.device_count()]))
    return axes


def tune_plan(module, *inputs, backend: str = "jnp",
              trials: Optional[int] = None, reps: Optional[int] = None,
              budget_s: Optional[float] = None,
              store=None) -> TuneResult:
    """Tune the plan for ``module`` on representative ``inputs``.

    ``inputs`` are the module's concrete arguments (query block +
    stored operands, in the module's own argument order); the query
    block's row count is the workload's ``m`` and what the tuned
    micro-batch is sized against.  Bounds: ``trials`` measured
    candidates (``REPRO_TUNE_TRIALS``), ``reps`` timed executes per
    candidate (``REPRO_TUNE_REPS``), ``budget_s`` wall-clock
    (``REPRO_TUNE_BUDGET_S``, 0 = unbounded).

    With a store (argument, else ``REPRO_PLAN_STORE``): a stored config
    for this workload short-circuits the whole search (``trials == 0``,
    ``from_store=True``); otherwise the winner is written back — config
    always, AOT executables when the plan is eligible and the jaxlib
    cooperates.
    """
    spec = extract_plan_spec(module)
    if spec is None:
        spec = extract_range_spec(module)
    if spec is None:
        raise ValueError("tune_plan needs a pure similarity/range module "
                         "(the interpreter path has no plan to tune)")
    trials = env_int("REPRO_TUNE_TRIALS", 24, min_value=1) \
        if trials is None else int(trials)
    reps = env_int("REPRO_TUNE_REPS", 3, min_value=1) \
        if reps is None else int(reps)
    budget_s = env_float("REPRO_TUNE_BUDGET_S", 0.0, min_value=0.0) \
        if budget_s is None else float(budget_s)
    store = active_store() if store is None else store
    ordered = _ordered_inputs(spec, inputs)
    spec = _canonical_spec(spec)

    if store is not None:
        cfg = store.load_config(spec, backend)
        if cfg is not None:
            plan = plan_for_config(spec, cfg)
            if plan is not None:
                _bump("store_hits")
                if tracer.enabled:
                    instant("tune.store_hit", pid="engine",
                            args={"backend": backend})
                plan.warm(*ordered[1:])
                return TuneResult(plan=plan, config=cfg, trials=0,
                                  from_store=True,
                                  base_s=float(cfg.get("base_s", 0.0)),
                                  best_s=float(cfg.get("best_s", 0.0)))

    t_start = time.perf_counter()
    m = int(np.asarray(ordered[0]).reshape(-1, spec.dim).shape[0])

    def out_of_budget() -> bool:
        return budget_s > 0 and time.perf_counter() - t_start > budget_s

    base_plan = get_plan(module_for_spec(spec), backend=backend)
    with trace_span("tune.baseline", pid="engine",
                    args=None if not tracer.enabled else
                    {"backend": backend, "n": spec.n, "dim": spec.dim}):
        base_s, base_out = _measure(base_plan, ordered, reps)

    best = _config_of(base_plan, backend)
    best_plan, best_s = base_plan, base_s
    history = [dict(best, wall_s=base_s, baseline=True)]
    used = 0
    for axis, values in _axis_values(spec, backend, m):
        for v in values:
            if used >= trials or out_of_budget():
                break
            if best.get(axis) == v:
                continue
            cfg = dict(best)
            cfg[axis] = v
            plan = plan_for_config(spec, cfg)
            if plan is None or plan is best_plan:
                continue
            used += 1
            _bump("trials")
            with trace_span("tune.trial", pid="engine",
                            args=None if not tracer.enabled else
                            {"axis": axis, "value": repr(v)}):
                try:
                    cand_s, out = _measure(plan, ordered, reps)
                except Exception:
                    # a candidate that cannot execute (e.g. pack=True
                    # refused) is simply not a winner
                    history.append(dict(cfg, wall_s=None, error=True))
                    continue
            ok = _verify(spec, base_out, out)
            if not ok:
                _bump("rejected")
            history.append(dict(cfg, wall_s=cand_s, verified=ok))
            if ok and cand_s < best_s:
                best, best_plan, best_s = _config_of(plan, backend), \
                    plan, cand_s

    _bump("tunes")
    best = dict(best, base_s=base_s, best_s=best_s, trials=used,
                speedup=base_s / best_s if best_s > 0 else 1.0)
    if tracer.enabled:
        instant("tune.winner", pid="engine",
                args={k: best[k] for k in ("tile_rows", "batch", "unroll",
                                           "speedup")})
    if store is not None:
        store.save_config(spec, backend, best)
        srcs = best_plan.warm(*ordered[1:])
        store.persist_executables(best_plan, srcs)
    return TuneResult(plan=best_plan, config=best, trials=used,
                      from_store=False, base_s=base_s, best_s=best_s,
                      history=history)


def warm_start_plan(plan: PlanBase) -> PlanBase:
    """The serving cold-start hook: swap a heuristically-built leaf plan
    for its stored tuned equivalent, when one exists.

    No store configured, no config recorded, a composite plan, or an
    explicitly sharded plan (the caller chose a topology) → the plan
    comes back unchanged.  The swap goes through ``get_plan``, so a
    configured store's AOT executables are adopted on the way — a fresh
    process serving a tuned workload skips the search *and* the XLA
    compile.
    """
    if not isinstance(plan, (SearchPlan, RangePlan)) or plan.shards > 1:
        return plan
    store = active_store()
    if store is None:
        return plan
    cfg = store.load_config(plan.spec, plan.backend)
    if cfg is None:
        return plan
    tuned = plan_for_config(plan.spec, cfg)
    return plan if tuned is None else tuned
