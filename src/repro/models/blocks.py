"""Per-family transformer blocks: init / apply / logical-axes triples.

Every block kind provides

* ``init_<kind>(key, cfg)``   — parameter pytree for ONE layer,
* ``<kind>_axes(cfg)``        — same-structure pytree of logical-axis tuples
                                (see `repro.models.sharding`); stacked layers
                                get a leading ``"layers"`` axis in model.py,
* ``apply_<kind>(p, x, cfg, *, ...)`` — pure forward, returns
  ``(x, new_cache_or_state)``.

Residual structure is pre-norm everywhere.  ``rules`` (ShardingRules) is
optional; when present, activations at block boundaries get sequence-
parallel sharding constraints and MoE runs expert-parallel.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers, mamba2, moe as moe_mod, xlstm
from .layers import apply_norm, attention, ffn, init_attention, init_ffn, init_norm

Params = Dict[str, Any]

NORM_AX = ("embed_act",)


def _norm_axes(cfg: ModelConfig) -> Params:
    a = {"scale": NORM_AX}
    if cfg.norm == "layernorm":
        a["bias"] = NORM_AX
    return a


def _attn_axes(cfg: ModelConfig) -> Params:
    a = {"wq": ("embed", "qkv_out"), "wk": ("embed", "qkv_out"),
         "wv": ("embed", "qkv_out"), "wo": ("qkv_out", "embed")}
    if cfg.qkv_bias:
        a.update(bq=("qkv_out",), bk=("qkv_out",), bv=("qkv_out",))
    return a


def _ffn_axes(cfg: ModelConfig) -> Params:
    a = {"wi": ("embed", "ffn"), "wo": ("ffn", "embed")}
    if cfg.act == "swiglu":
        a["wg"] = ("embed", "ffn")
    return a


def shard_act(x, rules, spec=("batch", "seq_act", None)):
    if rules is None:
        return x
    from .sharding import shard_like
    return shard_like(rules, x, spec)


# ---------------------------------------------------------------------------
# dense decoder block (dense / vlm / moe-dense-first families)
# ---------------------------------------------------------------------------


def init_dense_block(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 4)
    return {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "ln2": init_norm(cfg), "ffn": init_ffn(ks[1], cfg, d_ff)}


def dense_block_axes(cfg: ModelConfig) -> Params:
    return {"ln1": _norm_axes(cfg), "attn": _attn_axes(cfg),
            "ln2": _norm_axes(cfg), "ffn": _ffn_axes(cfg)}


def apply_dense_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                      positions, prefix_len: int = 0, cache=None,
                      rules=None) -> Tuple[jax.Array, Any]:
    x = shard_act(x, rules)
    a, new_cache = attention(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                             positions=positions, prefix_len=prefix_len,
                             cache=cache, rules=rules)
    x = x + a
    x = x + ffn(p["ffn"], apply_norm(p["ln2"], x, cfg), cfg)
    return shard_act(x, rules), new_cache


# ---------------------------------------------------------------------------
# MoE block
# ---------------------------------------------------------------------------


def init_moe_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "ln2": init_norm(cfg), "moe": moe_mod.init_moe(ks[1], cfg)}


def moe_block_axes(cfg: ModelConfig) -> Params:
    ma = {"router": ("embed", None),
          "wi": ("experts", "embed", None), "wg": ("experts", "embed", None),
          "wo": ("experts", None, "embed")}
    if cfg.n_shared_experts:
        ma.update(shared_wi=("embed", "ffn"), shared_wg=("embed", "ffn"),
                  shared_wo=("ffn", "embed"))
    return {"ln1": _norm_axes(cfg), "attn": _attn_axes(cfg),
            "ln2": _norm_axes(cfg), "moe": ma}


def apply_moe_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                    positions, cache=None, rules=None) -> Tuple[jax.Array, Any]:
    x = shard_act(x, rules)
    a, new_cache = attention(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                             positions=positions, cache=cache, rules=rules)
    x = x + a
    x = x + moe_mod.moe_ffn(p["moe"], apply_norm(p["ln2"], x, cfg), cfg,
                            rules=rules)
    return shard_act(x, rules), new_cache


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 hybrid)
# ---------------------------------------------------------------------------


def init_mamba_block(key, cfg: ModelConfig) -> Params:
    return {"ln": init_norm(cfg), "mamba": mamba2.init_mamba2(key, cfg)}


def mamba_block_axes(cfg: ModelConfig) -> Params:
    return {"ln": _norm_axes(cfg),
            "mamba": {"in_proj": ("embed", "ssm_inner"),
                      "conv_w": ("conv_k", None),
                      "A_log": (None,), "D": (None,), "dt_bias": (None,),
                      "out_proj": ("ssm_inner", "embed"),
                      "norm_scale": (None,)}}


def apply_mamba_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                      state=None, rules=None) -> Tuple[jax.Array, Any]:
    x = shard_act(x, rules)
    y, new_state = mamba2.mamba2_forward(p["mamba"], apply_norm(p["ln"], x, cfg),
                                         cfg, state=state)
    return shard_act(x + y, rules), new_state


# shared attention block (zamba2): full attn + MLP, weights shared across
# invocations (LoRA-free simplification of zamba2's shared block).
init_shared_attn_block = init_dense_block
shared_attn_block_axes = dense_block_axes
apply_shared_attn_block = apply_dense_block


# ---------------------------------------------------------------------------
# xLSTM pair block (mLSTM + sLSTM)
# ---------------------------------------------------------------------------


def init_xlstm_pair(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln_m": init_norm(cfg), "mlstm": xlstm.init_mlstm(ks[0], cfg),
            "ln_s": init_norm(cfg), "slstm": xlstm.init_slstm(ks[1], cfg)}


def xlstm_pair_axes(cfg: ModelConfig) -> Params:
    return {"ln_m": _norm_axes(cfg),
            "mlstm": {"wq": ("embed", "qkv_out"), "wk": ("embed", "qkv_out"),
                      "wv": ("embed", "qkv_out"), "wif": ("embed", None),
                      "wo": ("qkv_out", "embed"), "ogate": ("embed", "qkv_out")},
            "ln_s": _norm_axes(cfg),
            "slstm": {"wx": ("embed", None), "wh": ("embed", None),
                      "wo": ("embed", "embed")}}


def apply_xlstm_pair(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     state=None, rules=None) -> Tuple[jax.Array, Any]:
    x = shard_act(x, rules)
    sm = state["mlstm"] if state is not None else None
    ym, new_m = xlstm.mlstm_forward(p["mlstm"], apply_norm(p["ln_m"], x, cfg),
                                    cfg, state=sm)
    x = x + ym
    ss = state["slstm"] if state is not None else None
    ys, new_s = xlstm.slstm_forward(p["slstm"], apply_norm(p["ln_s"], x, cfg),
                                    cfg, state=ss)
    x = x + ys
    return shard_act(x, rules), {"mlstm": new_m, "slstm": new_s}


# ---------------------------------------------------------------------------
# encoder block (whisper encoder: bidirectional self-attn + FFN)
# ---------------------------------------------------------------------------


init_encoder_block = init_dense_block
encoder_block_axes = dense_block_axes


def apply_encoder_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                        positions, rules=None) -> Tuple[jax.Array, Any]:
    x = shard_act(x, rules)
    a, _ = attention(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                     positions=positions, causal=False, rules=rules)
    x = x + a
    x = x + ffn(p["ffn"], apply_norm(p["ln2"], x, cfg), cfg)
    return shard_act(x, rules), None


# ---------------------------------------------------------------------------
# decoder block with cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_xdec_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg), "self": init_attention(ks[0], cfg),
            "ln2": init_norm(cfg), "cross": init_attention(ks[1], cfg),
            "ln3": init_norm(cfg), "ffn": init_ffn(ks[2], cfg)}


def xdec_block_axes(cfg: ModelConfig) -> Params:
    return {"ln1": _norm_axes(cfg), "self": _attn_axes(cfg),
            "ln2": _norm_axes(cfg), "cross": _attn_axes(cfg),
            "ln3": _norm_axes(cfg), "ffn": _ffn_axes(cfg)}


def apply_xdec_block(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     positions, enc: jax.Array, cache=None,
                     rules=None) -> Tuple[jax.Array, Any]:
    """``cache``: {"self": attn cache} (cross kv recomputed from ``enc``)."""
    x = shard_act(x, rules)
    a, new_self = attention(p["self"], apply_norm(p["ln1"], x, cfg), cfg,
                            positions=positions,
                            cache=None if cache is None else cache["self"])
    x = x + a
    c, _ = attention(p["cross"], apply_norm(p["ln2"], x, cfg), cfg,
                     positions=positions, kv_source=enc)
    x = x + c
    x = x + ffn(p["ffn"], apply_norm(p["ln3"], x, cfg), cfg)
    new_cache = None if cache is None else {"self": new_self}
    return shard_act(x, rules), new_cache
