"""Model configuration for the assigned architecture pool.

One :class:`ModelConfig` describes any of the supported families:

* ``dense``  — pre-norm decoder-only transformer (GQA, RoPE, SwiGLU/GELU)
* ``moe``    — dense backbone with mixture-of-experts FFN layers
* ``hybrid`` — Mamba2 blocks + periodically-invoked shared attention block
  (zamba2 style)
* ``ssm``    — alternating mLSTM/sLSTM blocks (xLSTM style)
* ``vlm``    — decoder backbone consuming [patch embeddings; tokens] with a
  prefix-LM mask (PaliGemma style; vision tower is a stub per assignment)
* ``audio``  — encoder-decoder (Whisper style; conv frontend is a stub:
  inputs are precomputed frame embeddings)
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["ModelConfig", "reduced"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                   # 0 -> d_model // n_heads
    # attention details
    qkv_bias: bool = False
    rope: str = "standard"            # standard | 2d | none
    rope_theta: float = 10000.0
    act: str = "swiglu"               # swiglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0                 # expert hidden dim (fine-grained MoE)
    capacity_factor: float = 1.25
    first_dense_layers: int = 1       # deepseek: layer 0 is dense FFN
    dense_d_ff: int = 0               # FFN width of the first dense layers
    router_offload: str = "dense"     # dense | cam  (C4CAM top-k integration)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    shared_attn_every: int = 6        # zamba2: shared block period
    # xLSTM
    slstm_every: int = 2              # alternate mLSTM/sLSTM
    # enc-dec (audio)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper frame count after conv stub
    # vlm
    n_vision_tokens: int = 256        # paligemma patch tokens (stub)
    # numerics: params live in bf16 (the AdamW fp32 master copy carries
    # precision); compute in bf16 with fp32 softmax/norms/logits.
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # remat policy for train_step: none | full | dots
    remat: str = "full"

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.family == "audio"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing -> long_500k shape runs."""
        return self.family in ("hybrid", "ssm")

    @property
    def moe_layer(self) -> bool:
        return self.family == "moe"

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        emb = self.vocab * d
        attn = d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
        if self.family == "ssm":
            # mLSTM/sLSTM projections
            blk = 4 * d * d + 2 * d * self.d_ff if self.d_ff else 6 * d * d
            return emb + self.n_layers * blk
        if self.family == "hybrid":
            d_inner = self.ssm_expand * d
            mamba = d * (2 * d_inner + 2 * self.ssm_state) + d_inner * d
            shared = attn + 3 * d * self.d_ff
            n_shared = max(1, self.n_layers // self.shared_attn_every)
            return emb + self.n_layers * mamba + shared  # shared weights reused
        ff_mult = 3 if self.act == "swiglu" else 2
        dense_ff = ff_mult * d * self.d_ff
        if self.family == "moe":
            de = self.d_expert or self.d_ff
            moe_ff = (self.n_experts + self.n_shared_experts) * ff_mult * d * de \
                + d * self.n_experts
            n_moe = self.n_layers - self.first_dense_layers
            return emb + self.n_layers * attn + n_moe * moe_ff \
                + self.first_dense_layers * dense_ff
        layers = self.n_layers + self.n_encoder_layers
        extra = attn * self.n_encoder_layers if self.is_enc_dec else 0  # cross-attn
        return emb + layers * (attn + dense_ff) + extra

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        de = self.d_expert or self.d_ff
        ff_mult = 3 if self.act == "swiglu" else 2
        total = self.param_count()
        all_experts = self.n_experts * ff_mult * d * de
        active = (self.moe_top_k + self.n_shared_experts) * ff_mult * d * de
        n_moe = self.n_layers - self.first_dense_layers
        return total - n_moe * (all_experts - self.moe_top_k * ff_mult * d * de) \
            - 0  # shared experts always active


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
            n_heads: int = 4, d_ff: int = 128, vocab: int = 256,
            n_experts: Optional[int] = None) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kv = max(1, min(cfg.n_kv_heads * n_heads // max(cfg.n_heads, 1), n_heads))
    upd = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=kv, d_ff=d_ff, vocab=vocab, d_head=0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        shared_attn_every=1 if cfg.family == "hybrid" else cfg.shared_attn_every,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=16 if cfg.is_enc_dec else cfg.encoder_seq,
        n_vision_tokens=8 if cfg.family == "vlm" else cfg.n_vision_tokens,
        first_dense_layers=min(cfg.first_dense_layers, 1),
        remat="none",
    )
    if cfg.family == "moe":
        ne = n_experts if n_experts is not None else min(cfg.n_experts, 8)
        upd.update(n_experts=ne, moe_top_k=min(cfg.moe_top_k, 2),
                   n_shared_experts=min(cfg.n_shared_experts, 1),
                   d_expert=32 if cfg.d_expert else 0,
                   dense_d_ff=d_ff if cfg.dense_d_ff else 0,
                   capacity_factor=2.0)
    return replace(cfg, **upd)
