"""Shared transformer layers: norms, RoPE variants, GQA attention, FFN.

All parameters are plain dict pytrees; all functions are pure.  Weight
layout convention: 2-D weights are (d_in, d_out); scanned stacks get a
leading layer axis.  Compute runs in ``config.compute_dtype`` with fp32
logits/softmax/norm statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), pdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), pdtype(cfg))
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard, and chatglm-style 2d/partial)
# ---------------------------------------------------------------------------


def _rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    if cfg.rope == "none":
        return x
    dh = x.shape[-1]
    rot = dh // 2 if cfg.rope == "2d" else dh      # chatglm rotates half dims
    freqs = _rope_freqs(rot, cfg.rope_theta)       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    y = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    if rot < dh:
        y = jnp.concatenate([y, x[..., rot:].astype(jnp.float32)], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + cache + masks)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    dh, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, pdtype(cfg)),
        "wk": dense_init(ks[1], d, kv * dh, pdtype(cfg)),
        "wv": dense_init(ks[2], d, kv * dh, pdtype(cfg)),
        "wo": dense_init(ks[3], h * dh, d, pdtype(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), pdtype(cfg))
        p["bk"] = jnp.zeros((kv * dh,), pdtype(cfg))
        p["bv"] = jnp.zeros((kv * dh,), pdtype(cfg))
    return p


def _proj(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _pick_q_chunk(s: int, t: int) -> int:
    """Query-chunk heuristic bounding the live score block ~(qc x T)."""
    if s * t <= 1 << 21 or s <= 256:
        return s                      # small problem: one block
    if t >= 8192:
        return 256
    return 512


def _attn_block(qg: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
                q_start, t: int, causal: bool, prefix_len: int,
                kv_len=None) -> jax.Array:
    """One query block vs full K/V.

    qg: (B, qc, kv, g, dh); k/v: (B, T, kv, dh), both in compute dtype.
    ``q_start``: global index of the first query row (int or traced scalar).
    ``kv_len``: number of valid cache rows (traced) — keys >= kv_len masked.
    Returns (B, qc, kv, g, dh) fp32.

    Numerics follow flash attention on MXU hardware: QK^T in the native
    low precision with fp32 accumulation, masking+softmax in fp32, and
    the probabilities cast back to the value dtype for the PV matmul —
    the (qc, T) blocks that do leave registers are half-width.
    """
    qc = qg.shape[1]
    scores = jnp.einsum("bqkgd,btkd->bkgqt", qg, k.astype(qg.dtype),
                        preferred_element_type=jnp.float32) * scale
    ki = jnp.arange(t)[None, :]
    allow = jnp.ones((qc, t), bool)
    if causal:
        qi = q_start + jnp.arange(qc)[:, None]
        allow = ki <= qi
        if prefix_len:
            allow = allow | (ki < prefix_len)
    if kv_len is not None:
        allow = allow & (ki < kv_len)
    scores = jnp.where(allow[None, None, None], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgqt,btkd->bqkgd", attn.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def attn_core(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
              prefix_len: int = 0, kv_len=None, q_start=0,
              q_chunk: Optional[int] = None) -> jax.Array:
    """Memory-bounded GQA attention core.

    q: (B, S, H, dh); k/v: (B, T, KV, dh).  Chunks the query axis with a
    ``lax.scan`` so the live score block is (B, KV, g, qc, T) instead of the
    full (…, S, T) matrix — the pure-JAX analogue of flash attention's outer
    loop (inner KV blocking is left to XLA fusion; see kernels/flash for the
    Pallas TPU version).  Returns (B, S, H*dh) in q.dtype.
    """
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(b, s, kvh, g, dh)
    qc = q_chunk or _pick_q_chunk(s, t)

    if qc >= s:
        out = _attn_block(qg, k, v, scale=scale, q_start=q_start, t=t,
                          causal=causal, prefix_len=prefix_len, kv_len=kv_len)
        return out.reshape(b, s, h * dh).astype(q.dtype)

    pad = (-s) % qc
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = (s + pad) // qc
    qs = jnp.moveaxis(qg.reshape(b, nq, qc, kvh, g, dh), 1, 0)

    def body(start, q_blk):
        o = _attn_block(q_blk, k, v, scale=scale, q_start=start, t=t,
                        causal=causal, prefix_len=prefix_len, kv_len=kv_len)
        return start + qc, o

    # remat the block: without this the backward pass stacks each block's
    # (B, KV, g, qc, T) softmax + mask residuals across all nq chunks —
    # that one tensor dominated train-step memory at 32k context.
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, jnp.asarray(q_start, jnp.int32), qs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * qc, h * dh)[:, :s]
    return out.astype(q.dtype)


def _constrain_attention_layout(q, k, v, cfg: ModelConfig, rules,
                                include_heads: bool = False):
    """Pin the attention activation layout (GSPMD left alone splits the
    flattened h*dh projection across kv AND head_dim, yielding partial
    (B, kv, g, qc, T) score blocks that it then ALL-REDUCES — measured as
    the dominant collective for the non-16-divisible-head architectures).

    * heads divisible by the model axis -> classic TP attention (scores
      stay local per head shard).  Only applied when ``include_heads``:
      on the train/no-cache path GSPMD's own choice measured slightly
      better, but on the prefill path (where the cache layout anchors
      propagation) the pin is a large collective win (§Perf).
    * otherwise -> KV-parallel: shard the key/value LENGTH axis; softmax
      statistics and the (B, qc, h, dh) output block are psum'd — tiny
      next to score-sized transfers (flash-decoding style).
    """
    from .sharding import shard_like
    if rules is None:
        return q, k, v
    h = q.shape[2]
    if rules.resolve("heads", h) is not None:
        if include_heads:
            q = shard_like(rules, q, ("batch", None, "heads", None))
            k = shard_like(rules, k, ("batch", None, "kv_heads", None))
            v = shard_like(rules, v, ("batch", None, "kv_heads", None))
        return q, k, v
    if k.shape[1] % max(rules.model_size(), 1) == 0:
        q = shard_like(rules, q, ("batch", None, None, None))
        k = shard_like(rules, k, ("batch", "seq_act", None, None))
        v = shard_like(rules, v, ("batch", "seq_act", None, None))
    return q, k, v


def attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array,
              prefix_len: int = 0,
              cache: Optional[Dict[str, jax.Array]] = None,
              kv_source: Optional[jax.Array] = None,
              causal: bool = True,
              q_chunk: Optional[int] = None,
              rules=None,
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention.

    * ``cache``: {"k": (B, S_max, kv, dh), "v": ..., "len": ()} — new kv are
      written at position ``len``; attention spans the valid prefix.  With
      S > 1 this is the prefill path, with S == 1 decode.
    * ``kv_source``: cross-attention source (encoder states); causal
      masking is disabled and no RoPE is applied.
    * ``prefix_len``: bidirectional prefix (prefix-LM, e.g. vision tokens).
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _proj(x, p["wq"], p.get("bq")).reshape(b, s, h, dh)
    src = kv_source if kv_source is not None else x
    k = _proj(src, p["wk"], p.get("bk")).reshape(b, src.shape[1], kv, dh)
    v = _proj(src, p["wv"], p.get("bv")).reshape(b, src.shape[1], kv, dh)

    if kv_source is None:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)

    if cache is None:
        q, k, v = _constrain_attention_layout(q, k, v, cfg, rules)

    new_cache = None
    kv_len = None
    if cache is not None:
        start = cache["len"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, start, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, start, 0, 0))
        new_cache = {"k": ck, "v": cv, "len": start + s}
        k, v = ck, cv
        kv_len = start + s
        if s > 1:
            # prefill: same pathology as the no-cache path (partial-score
            # all-reduces), and here pinning helps divisible-head archs
            # too (the cache layout otherwise anchors a bad propagation);
            # one cache reshard per layer is orders of magnitude cheaper.
            q, k, v = _constrain_attention_layout(q, k, v, cfg, rules,
                                                  include_heads=True)

    out = attn_core(q, k, v, causal=causal and kv_source is None,
                    prefix_len=prefix_len, kv_len=kv_len,
                    q_start=0 if cache is None else cache["len"],
                    q_chunk=q_chunk)
    return _proj(out, p["wo"]), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, max_len, kv, dh), dtype),
            "v": jnp.zeros((batch, max_len, kv, dh), dtype),
            "len": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wi": dense_init(ks[0], d, f, pdtype(cfg)),
                "wg": dense_init(ks[1], d, f, pdtype(cfg)),
                "wo": dense_init(ks[2], f, d, pdtype(cfg))}
    return {"wi": dense_init(ks[0], d, f, pdtype(cfg)),
            "wo": dense_init(ks[2], f, d, pdtype(cfg))}


def ffn(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "wg" in p:
        h = jax.nn.silu(x @ p["wi"].astype(x.dtype)) * (x @ p["wg"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig) -> Params:
    p = {"tok": jax.random.normal(key, (cfg.vocab, cfg.d_model),
                                  pdtype(cfg)) * 0.02}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(jax.random.fold_in(key, 7), cfg.d_model,
                                  cfg.vocab, pdtype(cfg))
    return p


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["tok"].astype(cdtype(cfg))[tokens]


def logits(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)
