"""Model assembly for the assigned architecture pool.

Families (``cfg.family``):

* ``dense``  — decoder-only: scan over identical dense blocks.
* ``moe``    — ``first_dense_layers`` dense blocks, then MoE blocks (EP).
* ``hybrid`` — zamba2: groups of ``shared_attn_every`` Mamba2 blocks, each
  group preceded by ONE shared attention block (weights shared across all
  invocations — the zamba2 design; per-invocation LoRA omitted, DESIGN.md).
* ``ssm``    — xLSTM: scan over (mLSTM, sLSTM) pair blocks.
* ``vlm``    — PaliGemma: [vision patch embeddings; text] with a prefix-LM
  mask; vision tower is a stub (inputs are precomputed patch embeddings).
* ``audio``  — Whisper: encoder over precomputed frame embeddings (conv
  frontend stubbed) + decoder with cross attention.

All layer stacks are ``lax.scan`` over stacked parameters (compile-time
O(1) in depth); training remat wraps the scan body per ``cfg.remat``.
Caches are layer-stacked pytrees threaded through the same scans.

Three public entry points (all pure):

* ``forward(params, cfg, batch, rules)``            -> logits (train path)
* ``prefill(params, cfg, batch, cache, rules)``     -> (last logits, cache)
* ``decode_step(params, cfg, tokens, cache, rules)``-> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import blocks
from .blocks import shard_act
from .config import ModelConfig
from .layers import (apply_norm, attn_core, cdtype, embed, init_embedding,
                     init_norm, logits as unembed_logits, pdtype, _proj)
from . import mamba2 as mamba_mod, xlstm as xlstm_mod

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _stack_init(init_fn, key, n: int):
    """vmap an init over ``n`` layer keys -> stacked (n, ...) params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _with_layers(axes_tree):
    return jax.tree.map(lambda a: ("layers",) + tuple(a), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) int -> (B, S, d) float32 sinusoidal embedding (whisper stub)."""
    half = d // 2
    freq = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _groups(cfg: ModelConfig) -> Tuple[int, int]:
    per = max(1, cfg.shared_attn_every)
    n_groups = -(-cfg.n_layers // per)
    return n_groups, per


def _pairs(cfg: ModelConfig) -> int:
    return max(1, cfg.n_layers // 2)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    k_emb, k_blocks, k_extra = jax.random.split(key, 3)
    p: Params = {"embed": init_embedding(k_emb, cfg),
                 "final_norm": init_norm(cfg)}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["blocks"] = _stack_init(lambda k: blocks.init_dense_block(k, cfg),
                                  k_blocks, cfg.n_layers)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            dff = cfg.dense_d_ff or cfg.d_ff
            p["dense_blocks"] = _stack_init(
                lambda k: blocks.init_dense_block(k, cfg, dff), k_extra, nd)
        p["moe_blocks"] = _stack_init(lambda k: blocks.init_moe_block(k, cfg),
                                      k_blocks, cfg.n_layers - nd)
    elif fam == "hybrid":
        ng, per = _groups(cfg)
        p["mamba_blocks"] = _stack_init(
            lambda k: blocks.init_mamba_block(k, cfg), k_blocks, ng * per)
        p["shared_attn"] = blocks.init_shared_attn_block(k_extra, cfg)
    elif fam == "ssm":
        p["blocks"] = _stack_init(lambda k: blocks.init_xlstm_pair(k, cfg),
                                  k_blocks, _pairs(cfg))
    elif fam == "audio":
        p["enc_blocks"] = _stack_init(
            lambda k: blocks.init_encoder_block(k, cfg), k_extra,
            cfg.n_encoder_layers)
        p["enc_norm"] = init_norm(cfg)
        p["blocks"] = _stack_init(lambda k: blocks.init_xdec_block(k, cfg),
                                  k_blocks, cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def param_axes(cfg: ModelConfig) -> Params:
    emb = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        emb["unembed"] = ("embed", "vocab")
    a: Params = {"embed": emb, "final_norm": blocks._norm_axes(cfg)}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        a["blocks"] = _with_layers(blocks.dense_block_axes(cfg))
    elif fam == "moe":
        if cfg.first_dense_layers:
            a["dense_blocks"] = _with_layers(blocks.dense_block_axes(cfg))
        a["moe_blocks"] = _with_layers(blocks.moe_block_axes(cfg))
    elif fam == "hybrid":
        a["mamba_blocks"] = _with_layers(blocks.mamba_block_axes(cfg))
        a["shared_attn"] = blocks.shared_attn_block_axes(cfg)
    elif fam == "ssm":
        a["blocks"] = _with_layers(blocks.xlstm_pair_axes(cfg))
    elif fam == "audio":
        a["enc_blocks"] = _with_layers(blocks.encoder_block_axes(cfg))
        a["enc_norm"] = blocks._norm_axes(cfg)
        a["blocks"] = _with_layers(blocks.xdec_block_axes(cfg))
    return a


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def _attn_cache(cfg: ModelConfig, n_layers: int, b: int, m: int,
                dtype=jnp.bfloat16) -> Params:
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    shape = (n_layers, b, m, kv, dh)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "len": jnp.zeros((), jnp.int32)}


_ATTN_CACHE_AX = ("layers", "cache_batch", "cache_seq", "cache_kv", "cache_dim")


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return _attn_cache(cfg, cfg.n_layers, batch, max_len)
    if fam == "vlm":
        return _attn_cache(cfg, cfg.n_layers, batch,
                           max_len + cfg.n_vision_tokens)
    if fam == "hybrid":
        ng, per = _groups(cfg)
        d_inner, nh, dh, ds = mamba_mod._dims(cfg)
        return {
            "attn": _attn_cache(cfg, ng, batch, max_len),
            "mamba": {"ssm": jnp.zeros((ng, per, batch, nh, dh, ds),
                                       jnp.float32),
                      "conv": jnp.zeros((ng, per, batch, cfg.ssm_conv - 1,
                                         d_inner + 2 * ds), jnp.bfloat16)},
        }
    if fam == "ssm":
        lp = _pairs(cfg)
        nh, dh = xlstm_mod._dims(cfg)
        d = cfg.d_model
        return {
            "mlstm": {"C": jnp.zeros((lp, batch, nh, dh, dh), jnp.float32),
                      "n": jnp.zeros((lp, batch, nh, dh), jnp.float32),
                      "m": jnp.zeros((lp, batch, nh), jnp.float32)},
            "slstm": {"h": jnp.zeros((lp, batch, d), jnp.float32),
                      "c": jnp.zeros((lp, batch, d), jnp.float32),
                      "n": jnp.zeros((lp, batch, d), jnp.float32),
                      "m": jnp.full((lp, batch, d), -1e30, jnp.float32)},
        }
    if fam == "audio":
        kv, dh = cfg.n_kv_heads, cfg.head_dim
        cs = (cfg.n_layers, batch, cfg.encoder_seq, kv, dh)
        return {"self": _attn_cache(cfg, cfg.n_layers, batch, max_len),
                "cross": {"k": jnp.zeros(cs, jnp.bfloat16),
                          "v": jnp.zeros(cs, jnp.bfloat16)}}
    raise ValueError(fam)


def cache_axes(cfg: ModelConfig) -> Params:
    ac = {"k": _ATTN_CACHE_AX, "v": _ATTN_CACHE_AX, "len": ()}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return dict(ac)
    if fam == "hybrid":
        return {"attn": dict(ac),
                "mamba": {"ssm": (None, None, "cache_batch", "heads", None,
                                  None),
                          "conv": (None, None, "cache_batch", None,
                                   "ssm_inner")}}
    if fam == "ssm":
        return {"mlstm": {"C": (None, "cache_batch", "heads", None, None),
                          "n": (None, "cache_batch", "heads", None),
                          "m": (None, "cache_batch", None)},
                "slstm": {k: (None, "cache_batch", "embed_act")
                          for k in ("h", "c", "n", "m")}}
    if fam == "audio":
        return {"self": dict(ac),
                "cross": {"k": _ATTN_CACHE_AX, "v": _ATTN_CACHE_AX}}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# family-specific block stacks: one function per family, used by both the
# train path (cache=None) and the serve paths (cache threaded through scan)
# ---------------------------------------------------------------------------


def _sub_cache(cache, name):
    return None if cache is None else cache[name]


def _run_dense_stack(stack: Params, x, cfg, *, positions, prefix_len=0,
                     cache=None, rules=None, train=False):
    """Generic scan over a stacked block list with optional attn cache."""

    def body(carry, xs):
        xc, ln = carry
        p_l = xs[0]
        cache_l = None
        if cache is not None:
            cache_l = {"k": xs[1], "v": xs[2], "len": ln}
        fam_apply = blocks.apply_moe_block if "moe" in p_l else \
            blocks.apply_dense_block
        kw = {}
        if fam_apply is blocks.apply_dense_block:
            kw["prefix_len"] = prefix_len
        xc, new_cache = fam_apply(p_l, xc, cfg, positions=positions,
                                  cache=cache_l, rules=rules, **kw)
        ys = (new_cache["k"], new_cache["v"]) if cache is not None else 0
        return (xc, ln), ys

    fn = _maybe_remat(body, cfg) if train else body
    xs = (stack,) if cache is None else (stack, cache["k"], cache["v"])
    (x, _), ys = jax.lax.scan(fn, (x, 0 if cache is None else cache["len"]), xs)
    new_cache = None
    if cache is not None:
        s = x.shape[1]
        new_cache = {"k": ys[0], "v": ys[1], "len": cache["len"] + s}
    return x, new_cache


def _run_hybrid(p: Params, x, cfg, *, positions, cache=None, rules=None,
                train=False):
    ng, per = _groups(cfg)
    mstack = jax.tree.map(
        lambda a: a.reshape((ng, per) + a.shape[1:]), p["mamba_blocks"])
    shared = p["shared_attn"]

    def group_body(carry, xs):
        xc, ln = carry
        if cache is None:
            m_l = xs
            attn_cache = None
        else:
            m_l, mamba_states, ck, cv = xs
            attn_cache = {"k": ck, "v": cv, "len": ln}
        xc, new_attn = blocks.apply_shared_attn_block(
            shared, xc, cfg, positions=positions, cache=attn_cache,
            rules=rules)

        def mamba_body(xc2, xs2):
            if cache is None:
                blk = xs2
                st = None
            else:
                blk, st = xs2
            xc2, new_st = blocks.apply_mamba_block(blk, xc2, cfg, state=st,
                                                   rules=rules)
            return xc2, (new_st if cache is not None else 0)

        xs2 = m_l if cache is None else (m_l, mamba_states)
        xc, new_states = jax.lax.scan(mamba_body, xc, xs2)
        ys = ((new_attn["k"], new_attn["v"], new_states)
              if cache is not None else 0)
        return (xc, ln), ys

    fn = _maybe_remat(group_body, cfg) if train else group_body
    if cache is None:
        (x, _), _ = jax.lax.scan(fn, (x, 0), mstack)
        return x, None
    xs = (mstack, cache["mamba"], cache["attn"]["k"], cache["attn"]["v"])
    (x, _), ys = jax.lax.scan(fn, (x, cache["attn"]["len"]), xs)
    s = x.shape[1]
    new_cache = {"attn": {"k": ys[0], "v": ys[1],
                          "len": cache["attn"]["len"] + s},
                 "mamba": ys[2]}
    return x, new_cache


def _run_ssm(p: Params, x, cfg, *, cache=None, rules=None, train=False):
    def body(carry, xs):
        xc = carry
        if cache is None:
            blk = xs
            st = None
        else:
            blk, st = xs
        xc, new_st = blocks.apply_xlstm_pair(blk, xc, cfg, state=st,
                                             rules=rules)
        return xc, (new_st if cache is not None else 0)

    fn = _maybe_remat(body, cfg) if train else body
    xs = p["blocks"] if cache is None else (p["blocks"], cache)
    x, ys = jax.lax.scan(fn, x, xs)
    return x, (ys if cache is not None else None)


def _run_encoder(p: Params, frames, cfg, *, rules=None, train=False):
    b, t, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    x = frames.astype(cdtype(cfg)) + _sinusoidal(pos, cfg.d_model).astype(
        cdtype(cfg))

    def body(xc, blk):
        xc, _ = blocks.apply_encoder_block(blk, xc, cfg, positions=pos,
                                           rules=rules)
        return xc, 0

    fn = _maybe_remat(body, cfg) if train else body
    x, _ = jax.lax.scan(fn, x, p["enc_blocks"])
    return apply_norm(p["enc_norm"], x, cfg)


def _cross_kv(p_attn: Params, enc: jax.Array, cfg: ModelConfig):
    b, t, _ = enc.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = _proj(enc, p_attn["wk"], p_attn.get("bk")).reshape(b, t, kv, dh)
    v = _proj(enc, p_attn["wv"], p_attn.get("bv")).reshape(b, t, kv, dh)
    return k, v


def _cross_attend(p_attn: Params, xn: jax.Array, cfg: ModelConfig, ck, cv):
    b, s, _ = xn.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = _proj(xn, p_attn["wq"], p_attn.get("bq")).reshape(b, s, h, dh)
    out = attn_core(q, ck.astype(xn.dtype), cv.astype(xn.dtype), causal=False)
    return _proj(out, p_attn["wo"])


def _run_xdec(p: Params, x, cfg, *, positions, enc=None, cache=None,
              rules=None, train=False):
    """Decoder stack; cross-KV comes from ``enc`` (train/prefill computes it
    per layer) or from the cache (decode)."""

    def body(carry, xs):
        xc, ln = carry
        if cache is None:
            blk = xs
            self_cache = None
            ck = cv = None
        else:
            blk, sk, sv, ck, cv = xs
            self_cache = {"k": sk, "v": sv, "len": ln}
        xc0 = shard_act(xc, rules)
        xn = apply_norm(blk["ln1"], xc0, cfg)
        from .layers import attention
        a, new_self = attention(blk["self"], xn, cfg, positions=positions,
                                cache=self_cache, rules=rules)
        xc = xc0 + a
        xn2 = apply_norm(blk["ln2"], xc, cfg)
        if ck is None:
            ck, cv = _cross_kv(blk["cross"], enc, cfg)
        xc = xc + _cross_attend(blk["cross"], xn2, cfg, ck, cv)
        from .layers import ffn as ffn_apply
        xc = xc + ffn_apply(blk["ffn"], apply_norm(blk["ln3"], xc, cfg), cfg)
        xc = shard_act(xc, rules)
        ys = (new_self["k"], new_self["v"]) if cache is not None else 0
        return (xc, ln), ys

    fn = _maybe_remat(body, cfg) if train else body
    if cache is None:
        (x, _), _ = jax.lax.scan(fn, (x, 0), p["blocks"])
        return x, None
    xs = (p["blocks"], cache["self"]["k"], cache["self"]["v"],
          cache["cross"]["k"], cache["cross"]["v"])
    (x, _), ys = jax.lax.scan(fn, (x, cache["self"]["len"]), xs)
    s = x.shape[1]
    new_cache = {"self": {"k": ys[0], "v": ys[1],
                          "len": cache["self"]["len"] + s},
                 "cross": cache["cross"]}
    return x, new_cache


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _embed_tokens(p: Params, tokens, cfg, positions):
    x = embed(p["embed"], tokens, cfg)
    # absolute (sinusoidal) positions: audio decoder always; attention
    # families configured without RoPE.  SSM/hybrid are position-free.
    if cfg.family == "audio" or (
            cfg.rope == "none" and cfg.family in ("dense", "moe", "vlm")):
        x = x + _sinusoidal(positions, cfg.d_model).astype(x.dtype)
    return x


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            rules=None, train: bool = True,
            return_hidden: bool = False) -> jax.Array:
    """Full-sequence logits (teacher forcing).  ``batch["tokens"]: (B, S)``.

    vlm: ``batch["vision"]: (B, n_vision_tokens, d_model)`` prepended with a
    bidirectional prefix mask; returned logits cover only text positions.
    audio: ``batch["frames"]: (B, encoder_seq, d_model)`` through the
    encoder; decoder is teacher-forced on ``tokens``.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    fam = cfg.family
    prefix = cfg.n_vision_tokens if fam == "vlm" else 0
    positions = jnp.broadcast_to(jnp.arange(prefix + s)[None], (b, prefix + s))

    x = _embed_tokens(params, tokens, cfg, positions[:, prefix:])
    if fam == "vlm":
        vis = batch["vision"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    x = shard_act(x, rules)

    if fam in ("dense", "vlm"):
        x, _ = _run_dense_stack(params["blocks"], x, cfg, positions=positions,
                                prefix_len=prefix, rules=rules, train=train)
    elif fam == "moe":
        if cfg.first_dense_layers:
            x, _ = _run_dense_stack(params["dense_blocks"], x, cfg,
                                    positions=positions, rules=rules,
                                    train=train)
        x, _ = _run_dense_stack(params["moe_blocks"], x, cfg,
                                positions=positions, rules=rules, train=train)
    elif fam == "hybrid":
        x, _ = _run_hybrid(params, x, cfg, positions=positions, rules=rules,
                           train=train)
    elif fam == "ssm":
        x, _ = _run_ssm(params, x, cfg, rules=rules, train=train)
    elif fam == "audio":
        enc = _run_encoder(params, batch["frames"], cfg, rules=rules,
                           train=train)
        x, _ = _run_xdec(params, x, cfg, positions=positions, enc=enc,
                         rules=rules, train=train)
    if fam == "vlm":
        x = x[:, prefix:]
    x = apply_norm(params["final_norm"], x, cfg)
    if return_hidden:
        return x
    return unembed_logits(params["embed"], x, cfg)


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            cache: Params, rules=None) -> Tuple[jax.Array, Params]:
    """Prefill the cache with ``batch["tokens"]``; returns last-pos logits."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    fam = cfg.family
    prefix = cfg.n_vision_tokens if fam == "vlm" else 0
    positions = jnp.broadcast_to(jnp.arange(prefix + s)[None], (b, prefix + s))

    x = _embed_tokens(params, tokens, cfg, positions[:, prefix:])
    if fam == "vlm":
        x = jnp.concatenate([batch["vision"].astype(x.dtype), x], axis=1)
    x = shard_act(x, rules)

    if fam in ("dense", "vlm"):
        x, cache = _run_dense_stack(params["blocks"], x, cfg,
                                    positions=positions, prefix_len=prefix,
                                    cache=cache, rules=rules)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            dense_cache = {"k": cache["k"][:nd], "v": cache["v"][:nd],
                           "len": cache["len"]}
            x, dc = _run_dense_stack(params["dense_blocks"], x, cfg,
                                     positions=positions, cache=dense_cache,
                                     rules=rules)
        moe_cache = {"k": cache["k"][nd:], "v": cache["v"][nd:],
                     "len": cache["len"]}
        x, mc = _run_dense_stack(params["moe_blocks"], x, cfg,
                                 positions=positions, cache=moe_cache,
                                 rules=rules)
        k = jnp.concatenate([dc["k"], mc["k"]], 0) if nd else mc["k"]
        v = jnp.concatenate([dc["v"], mc["v"]], 0) if nd else mc["v"]
        cache = {"k": k, "v": v, "len": mc["len"]}
    elif fam == "hybrid":
        x, cache = _run_hybrid(params, x, cfg, positions=positions,
                               cache=cache, rules=rules)
    elif fam == "ssm":
        x, cache = _run_ssm(params, x, cfg, cache=cache, rules=rules)
    elif fam == "audio":
        enc = _run_encoder(params, batch["frames"], cfg, rules=rules)
        ck, cv = jax.vmap(
            lambda blk: _cross_kv(blk["cross"], enc, cfg))(params["blocks"])
        cache = {"self": cache["self"], "cross": {"k": ck, "v": cv}}
        x, cache = _run_xdec(params, x, cfg, positions=positions, cache=cache,
                             rules=rules)

    x = apply_norm(params["final_norm"], x[:, -1:], cfg)
    return unembed_logits(params["embed"], x, cfg), cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Params, rules=None) -> Tuple[jax.Array, Params]:
    """One decode step.  tokens: (B, 1) -> logits (B, 1, V), new cache."""
    b, s = tokens.shape
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        ln = cache["len"]
    elif fam == "hybrid":
        ln = cache["attn"]["len"]
    elif fam == "audio":
        ln = cache["self"]["len"]
    else:  # ssm: position only matters for rope-free recurrence
        ln = jnp.zeros((), jnp.int32)
    positions = jnp.broadcast_to(ln[None, None], (b, s)) + jnp.arange(s)[None]

    x = _embed_tokens(params, tokens, cfg, positions)
    x = shard_act(x, rules, ("batch", None, None))

    if fam in ("dense", "vlm"):
        prefix = cfg.n_vision_tokens if fam == "vlm" else 0
        x, cache = _run_dense_stack(params["blocks"], x, cfg,
                                    positions=positions, prefix_len=prefix,
                                    cache=cache, rules=rules)
    elif fam == "moe":
        nd = cfg.first_dense_layers
        if nd:
            dense_cache = {"k": cache["k"][:nd], "v": cache["v"][:nd],
                           "len": cache["len"]}
            x, dc = _run_dense_stack(params["dense_blocks"], x, cfg,
                                     positions=positions, cache=dense_cache,
                                     rules=rules)
        moe_cache = {"k": cache["k"][nd:], "v": cache["v"][nd:],
                     "len": cache["len"]}
        x, mc = _run_dense_stack(params["moe_blocks"], x, cfg,
                                 positions=positions, cache=moe_cache,
                                 rules=rules)
        k = jnp.concatenate([dc["k"], mc["k"]], 0) if nd else mc["k"]
        v = jnp.concatenate([dc["v"], mc["v"]], 0) if nd else mc["v"]
        cache = {"k": k, "v": v, "len": mc["len"]}
    elif fam == "hybrid":
        x, cache = _run_hybrid(params, x, cfg, positions=positions,
                               cache=cache, rules=rules)
    elif fam == "ssm":
        x, cache = _run_ssm(params, x, cfg, cache=cache, rules=rules)
    elif fam == "audio":
        x, cache = _run_xdec(params, x, cfg, positions=positions, cache=cache,
                             rules=rules)

    x = apply_norm(params["final_norm"], x, cfg)
    return unembed_logits(params["embed"], x, cfg), cache
