"""Mixture-of-experts FFN with capacity-based scatter/gather dispatch.

Design targets (DESIGN.md §6):

* **EP-shardable**: expert-stacked weights (E, d_in, d_out) shard the E axis
  over the ``model`` mesh axis; dispatch/combine become all-to-all-style
  collectives under pjit.
* **Compile-economical**: no (T, E, C) one-hot dispatch tensors; assignment
  uses a cumsum position + scatter-add, O(T*E) ints.
* **C4CAM integration**: the router is a ``matmul -> topk`` dataflow —
  exactly the paper's DotProdSimPattern.  With ``router_offload="cam"`` the
  top-k runs through the CAM search primitive (`repro.kernels`), i.e. the
  accelerator the paper compiles for; "dense" keeps plain jnp.  Both give
  identical routing decisions (ties break toward lower expert index in both
  paths).

Supports deepseek-moe (fine-grained: 64 routed top-6 + 2 always-on shared
experts) and phi3.5-moe (16 routed top-2).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init, pdtype

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    de = cfg.d_expert or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 7)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, pdtype(cfg)),
        "wi": jax.random.normal(ks[1], (e, d, de), pdtype(cfg)) * scale,
        "wg": jax.random.normal(ks[2], (e, d, de), pdtype(cfg)) * scale,
        "wo": jax.random.normal(ks[3], (e, de, d), pdtype(cfg)) / np.sqrt(de),
    }
    if cfg.n_shared_experts:
        ds = de * cfg.n_shared_experts
        p["shared_wi"] = dense_init(ks[4], d, ds, pdtype(cfg))
        p["shared_wg"] = dense_init(ks[5], d, ds, pdtype(cfg))
        p["shared_wo"] = dense_init(ks[6], ds, d, pdtype(cfg))
    return p


def router_topk(xt: jax.Array, router_w: jax.Array, k: int, offload: str
                ) -> Tuple[jax.Array, jax.Array]:
    """Select top-k experts: (T, D) tokens x (D, E) router -> (T,k) idx.

    ``offload="cam"`` treats the router rows as CAM-stored patterns and runs
    the C4CAM best-match primitive (dot metric, tiled subarray semantics) —
    the paper's DotProdSimPattern (matmul -> topk) executed on the CAM
    substrate.  ``offload="dense"`` is the plain jnp baseline.  Both use the
    same stable lower-index tie-breaking; scores are computed in fp32 in
    both paths (routing decisions agree up to fp32 summation order).
    """
    if offload == "cam":
        from ..kernels import ref as kref
        e, d = router_w.shape[1], router_w.shape[0]
        vals, idx = kref.cam_topk_tiled(
            xt.astype(jnp.float32), router_w.T.astype(jnp.float32),
            metric="dot", k=k, largest=True,
            tile_rows=min(32, e), dims_per_tile=min(128, d))
        return vals, idx
    scores = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def _moe_routed(router_w: jax.Array, wi: jax.Array, wg: jax.Array,
                wo: jax.Array, xt: jax.Array, cfg: ModelConfig, *,
                e_global: int, e_offset: int) -> jax.Array:
    """Routed-expert compute over a *local* expert slice ``[e_offset, +E_loc)``.

    Router scores/softmax/top-k span all ``e_global`` experts (router weights
    are replicated — deterministic across shards); dispatch and the expert
    FFNs touch only the local slice.  Used both by the single-device path
    (slice == all experts) and per-shard inside the EP ``shard_map`` (the
    cross-shard combine is a ``psum`` in the caller).
    """
    t, d = xt.shape
    e_loc = wi.shape[0]
    k = cfg.moe_top_k

    scores = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
    gate_all = jax.nn.softmax(scores, axis=-1)
    _, expert_idx = router_topk(xt, router_w, k, cfg.router_offload)
    expert_idx = jax.lax.stop_gradient(expert_idx)
    gates = jnp.take_along_axis(gate_all, expert_idx, axis=-1)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    capacity = int(np.ceil(t * k / e_global * cfg.capacity_factor))
    capacity = max(capacity, 8)

    # queue position of each (token, slot) within its *global* expert
    onehot = jax.nn.one_hot(expert_idx, e_global, dtype=jnp.int32)  # (T,k,E)
    flat = onehot.reshape(t * k, e_global)
    pos = jnp.cumsum(flat, axis=0) - 1                              # (T*k, E)
    pos = jnp.take_along_axis(pos, expert_idx.reshape(-1, 1), axis=1)[:, 0]

    eidx = expert_idx.reshape(-1)
    local = (eidx >= e_offset) & (eidx < e_offset + e_loc)
    keep = (pos < capacity) & local
    eloc_idx = jnp.where(local, eidx - e_offset, 0)
    safe_pos = jnp.where(keep, pos, capacity - 1)

    # dispatch: (E_loc, C, D) buffers
    buf = jnp.zeros((e_loc, capacity, d), xt.dtype)
    src = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(xt.dtype)
    buf = buf.at[eloc_idx, safe_pos].add(src, mode="drop")

    hi = jnp.einsum("ecd,edf->ecf", buf, wi.astype(xt.dtype))
    hg = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xt.dtype))
    h = jax.nn.silu(hi) * hg
    out = jnp.einsum("ecf,efd->ecd", h, wo.astype(xt.dtype))

    # combine: gather back and weight (dropped / remote slots weight 0)
    gathered = out[eloc_idx, safe_pos]                              # (T*k, D)
    w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(xt.dtype)
    return (gathered * w[:, None]).reshape(t, k, d).sum(axis=1)


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
            rules=None) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    With ``rules`` (a :class:`~repro.models.sharding.ShardingRules` over a
    multi-device mesh) and ``E % model_size == 0``, the routed experts run
    expert-parallel under ``shard_map``: tokens stay replicated across the
    ``model`` axis (they are data-sharded only), every model shard computes
    the contribution of its local experts, and a ``psum`` over ``model``
    combines.  No all-to-all is needed because each shard already holds its
    data-shard's tokens — the EP collective cost is one (B,S,D) all-reduce.
    """
    b, s, d = x.shape
    xt_shape_back = (b, s, d)
    e = cfg.n_experts

    ep = (rules is not None and rules.model_axis is not None
          and rules.model_size() > 1 and e % rules.model_size() == 0)
    if ep:
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map as _shard_map
        except ImportError:                     # pragma: no cover
            from jax.experimental.shard_map import shard_map as _shard_map
        mesh = rules.mesh
        maxis = rules.model_axis
        bd = rules.batch_axes
        n_shards = rules.model_size()
        e_loc = e // n_shards

        # combine with psum_scatter onto the sequence-parallel layout when
        # S divides the model axis: half the ring cost of a full psum AND
        # the result lands directly in the layer-boundary (S@model)
        # sharding (no re-shard before the residual add)
        scatter = s % n_shards == 0

        def body(xt_loc, router_w, wi, wg, wo):
            pos = jax.lax.axis_index(maxis)
            y = _moe_routed(router_w, wi, wg, wo,
                            xt_loc.reshape(-1, d), cfg,
                            e_global=e, e_offset=pos * e_loc)
            y = y.reshape(xt_loc.shape)
            if scatter:
                return jax.lax.psum_scatter(y, maxis, scatter_dimension=1,
                                            tiled=True)
            return jax.lax.psum(y, maxis)

        batch_spec = (bd if len(bd) > 1 else bd[0]) if bd else None
        bspec = P(batch_spec, None, None)
        out_spec = P(batch_spec, maxis, None) if scatter else bspec
        y = _shard_map(
            body, mesh=mesh,
            in_specs=(bspec, P(None, None), P(maxis, None, None),
                      P(maxis, None, None), P(maxis, None, None)),
            out_specs=out_spec, check_vma=False,
        )(x, p["router"], p["wi"], p["wg"], p["wo"])
        yt = y.reshape(b * s, d)
        xt = x.reshape(b * s, d)
    else:
        xt = x.reshape(b * s, d)
        yt = _moe_routed(p["router"], p["wi"], p["wg"], p["wo"], xt, cfg,
                         e_global=e, e_offset=0)

    if cfg.n_shared_experts:
        hs = jax.nn.silu(xt @ p["shared_wi"].astype(x.dtype)) \
            * (xt @ p["shared_wg"].astype(x.dtype))
        yt = yt + hs @ p["shared_wo"].astype(x.dtype)
    return yt.reshape(xt_shape_back)


def aux_load_balance_loss(scores: jax.Array, expert_idx: jax.Array,
                          n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (optional in train loop)."""
    gate = jax.nn.softmax(scores.astype(jnp.float32), -1)
    me = gate.mean(0)
    ce = jnp.bincount(expert_idx.reshape(-1), length=n_experts) / expert_idx.size
    return n_experts * jnp.sum(me * ce)
