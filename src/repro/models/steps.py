"""Train / serve step factories.

``make_train_step(cfg, rules, schedule, opt_cfg)`` builds the pure
``(state, batch) -> (state, metrics)`` function that launch/train.py jits
with explicit in/out shardings; ``make_prefill_step`` / ``make_decode_step``
do the same for serving.  The loss is next-token cross entropy computed
blockwise over the sequence so the (B, S, V) logits tensor never
materializes in full (the live block is (B, s_blk, V)).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import model as model_mod
from .config import ModelConfig
from .layers import apply_norm, cdtype
from ..optim import AdamWConfig, OptState, adamw_init, adamw_update, Schedule

Params = Dict[str, Any]


class TrainState(NamedTuple):
    params: Params
    opt: OptState
    step: jax.Array
    comp: Any = ()          # gradient-compression error-feedback state


def init_train_state(key, cfg: ModelConfig, compressor=None,
                     opt_cfg: AdamWConfig = AdamWConfig()) -> TrainState:
    params = model_mod.init_params(key, cfg)
    comp = compressor.init(params) if compressor is not None else ()
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32), comp=comp)


# ---------------------------------------------------------------------------
# blockwise cross entropy
# ---------------------------------------------------------------------------


def _xent_block(logits: jax.Array, labels: jax.Array,
                mask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sum of masked token losses + correct-token count for one block."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = (lse - ll) * mask
    acc = (jnp.argmax(logits, -1) == labels) * mask
    return loss.sum(), acc.sum()


def blockwise_xent(hidden: jax.Array, labels: jax.Array, mask: jax.Array,
                   params: Params, cfg: ModelConfig,
                   block: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Cross entropy from final *hidden* states, unembedding block-by-block.

    hidden: (B, S, D) post-final-norm; labels/mask: (B, S).
    Returns (mean loss, mean accuracy) over mask.
    """
    from .layers import logits as unembed
    b, s, d = hidden.shape
    blk = min(block, s)
    if s % blk:
        pad = blk - s % blk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        s += pad
    nb = s // blk
    hs = jnp.moveaxis(hidden.reshape(b, nb, blk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nb, blk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nb, blk), 1, 0)

    def body(carry, xs):
        h, l, m = xs
        lg = unembed(params["embed"], h.astype(cdtype(cfg)), cfg)
        lsum, asum = _xent_block(lg, l, m)
        return (carry[0] + lsum, carry[1] + asum), 0

    (loss_sum, acc_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    denom = jnp.maximum(mask.sum(), 1.0)
    return loss_sum / denom, acc_sum / denom


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            rules=None) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token LM loss.  ``batch["tokens"] (B, S)``; labels are the
    tokens shifted left; the final position is masked out.  Extra modality
    inputs (vision/frames) pass through to the model."""
    tokens = batch["tokens"]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    if "mask" in batch:
        mask = mask * batch["mask"].astype(jnp.float32)

    # forward WITHOUT the unembedding: redo final norm here so the logits
    # can be formed blockwise (model.forward returns full logits; we reuse
    # its internals via the hidden path).
    hidden = forward_hidden(params, cfg, batch, rules)
    loss, acc = blockwise_xent(hidden, labels, mask, params, cfg)
    return loss, {"loss": loss, "accuracy": acc}


def forward_hidden(params: Params, cfg: ModelConfig,
                   batch: Dict[str, jax.Array], rules=None) -> jax.Array:
    """model.forward minus the unembedding (returns post-norm hidden)."""
    # Reuse model.forward's plumbing by monkey-free composition: the model
    # module exposes the same stacks; here we replicate the tail.
    return model_mod.forward(params, cfg, batch, rules=rules, train=True,
                             return_hidden=True)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, schedule: Schedule,
                    opt_cfg: AdamWConfig = AdamWConfig(), rules=None,
                    compressor=None, microbatches: int = 1,
                    acc_dtype: str = "float32"):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (pure).

    ``compressor``: optional error-feedback gradient compressor
    (`repro.distributed.compression`); its residual state rides in
    ``state.comp`` and is sharded like the parameters.

    ``microbatches > 1``: gradient accumulation — the global batch is split
    into ``k`` sequential microbatches (``lax.scan``), dividing the live
    activation footprint (remat-saved layer inputs and transients) by ``k``
    at the cost of ``k`` forward/backward passes over ``1/k`` of the data.
    The per-device batch dim must stay divisible by the data axis, so ``k``
    must divide ``global_batch / data_parallelism``.
    """

    def constrain_grads(g):
        """Pin gradients to the parameters' (FSDP x TP) sharding.

        Without this GSPMD reduces data-parallel gradients with FULL-tensor
        fp32 all-reduces per layer (measured: the dominant collective term
        for large dense models); the constraint turns them into
        reduce-scatters onto the ZeRO shard — 2(n-1)/n -> (n-1)/n ring cost
        on 1/16th the bytes."""
        if rules is None:
            return g
        from jax.sharding import NamedSharding
        from .model import param_axes
        from .sharding import logical_spec
        spec = logical_spec(rules, g, param_axes(cfg))
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(rules.mesh, s)), g, spec)

    def grad_of(params, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, rules), has_aux=True)(params)
        return (loss, m), constrain_grads(g)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = grad_of(state.params, batch)
        else:
            k = microbatches

            def split(x):
                b = x.shape[0]
                return jnp.moveaxis(
                    x.reshape((k, b // k) + x.shape[1:]), 0, 0)

            mb = jax.tree.map(split, batch)

            acc_dt = jnp.dtype(acc_dtype)

            def acc_body(carry, mbatch):
                g_acc, l_acc, a_acc = carry
                (loss, m), g = grad_of(state.params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32) / k).astype(acc_dt),
                    g_acc, g)
                return (g_acc, l_acc + loss / k,
                        a_acc + m["accuracy"] / k), 0

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            (grads, loss, acc), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros(()), jnp.zeros(())), mb)
            metrics = {"loss": loss, "accuracy": acc}

        comp_state = state.comp
        if compressor is not None:
            grads, comp_state = compressor(grads, comp_state)
        lr = schedule(state.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr, opt_cfg)
        metrics = {**metrics, **opt_metrics, "step": state.step}
        return TrainState(new_params, new_opt, state.step + 1,
                          comp_state), metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, rules=None):
    def prefill_step(params: Params, batch: Dict[str, jax.Array],
                     cache: Params):
        return model_mod.prefill(params, cfg, batch, cache, rules=rules)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules=None):
    def decode_step(params: Params, tokens: jax.Array, cache: Params):
        return model_mod.decode_step(params, cfg, tokens, cache, rules=rules)
    return decode_step
