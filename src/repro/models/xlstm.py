"""xLSTM blocks (sLSTM + mLSTM) for the xlstm-125m architecture.

mLSTM: matrix-memory LSTM — ``C_t = f_t C_{t-1} + i_t v_t k_t^T``, read out
as ``h_t = (C_t q_t) / max(|n_t . q_t|, 1)``; exponential gating with a
log-domain stabilizer state ``m_t``.  Parallelized over the sequence with
the same chunked-scan trick as Mamba2 (decay products inside a chunk are
cumulative sums of log f).

sLSTM: scalar-memory LSTM with exponential input gate and normalizer state;
sequential by construction — implemented as a per-head ``lax.scan`` over
time (the paper's own formulation; its recurrence is cheap: O(d) per step).

Both are O(S) in sequence length, qualifying xlstm for ``long_500k``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init, pdtype

Params = Dict[str, Any]


def _dims(cfg: ModelConfig) -> Tuple[int, int]:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    return nh, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    nh, dh = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, d, pdtype(cfg)),
        "wk": dense_init(ks[1], d, d, pdtype(cfg)),
        "wv": dense_init(ks[2], d, d, pdtype(cfg)),
        "wif": dense_init(ks[3], d, 2 * nh, pdtype(cfg)),   # input+forget gate
        "wo": dense_init(ks[4], d, d, pdtype(cfg)),
        "ogate": dense_init(ks[5], d, d, pdtype(cfg)),
    }


_IG_CLIP = 15.0   # input-gate pre-activation clip (both paths, identical)


def mlstm_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  state: Optional[Dict[str, jax.Array]] = None,
                  chunk: int = 256
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D).  state: {"C": (B,nh,dh,dh), "n": (B,nh,dh), "m": (B,nh)}.

    Prefill uses a chunked scan (O(S) like Mamba2's SSD): quadratic gated
    linear attention inside each chunk, matrix-state carry across chunks.
    The chunked path carries the SAME log-domain running-max stabilizer
    ``m`` as the exact decode recurrence (xLSTM's ``max(|n.q|, 1)``
    read-out clamp is scale-dependent, so the stabilized and unstabilized
    forms are NOT output-equivalent — tests pin chunked == stepwise).
    """
    b, s, d = x.shape
    nh, dh = _dims(cfg)
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, nh, dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, nh, dh) / np.sqrt(dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, nh, dh)
    gates = (x @ p["wif"].astype(x.dtype)).astype(jnp.float32)
    ig = jnp.clip(gates[..., :nh], -_IG_CLIP, _IG_CLIP)     # (B,S,nh)
    logf = jax.nn.log_sigmoid(gates[..., nh:])

    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    if state is not None and s == 1:
        m_prev, C_prev, n_prev = state["m"], state["C"], state["n"]
        m_t = jnp.maximum(logf[:, 0] + m_prev, ig[:, 0])
        fsc = jnp.exp(logf[:, 0] + m_prev - m_t)
        isc = jnp.exp(ig[:, 0] - m_t)
        C = fsc[..., None, None] * C_prev \
            + isc[..., None, None] * (vf[:, 0, :, :, None] * kf[:, 0, :, None, :])
        n = fsc[..., None] * n_prev + isc[..., None] * kf[:, 0]
        num = jnp.einsum("bhvk,bhk->bhv", C, qf[:, 0])
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf[:, 0])), 1.0)
        h = (num / den[..., None]).reshape(b, 1, d)
        new_state = {"C": C, "n": n, "m": m_t}
    else:
        pad = (-s) % chunk
        cs = min(chunk, s + pad)
        if pad:
            qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
            # -inf input gate: padded positions contribute exactly zero to
            # the carried state (exp(-inf) = 0)
            ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)),
                         constant_values=-1e30)
            logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        nc = (s + pad) // cs

        def to_chunks(t, extra):
            return jnp.moveaxis(t.reshape((b, nc, cs) + extra), 1, 0)

        inputs = (to_chunks(qf, (nh, dh)), to_chunks(kf, (nh, dh)),
                  to_chunks(vf, (nh, dh)), to_chunks(ig, (nh,)),
                  to_chunks(logf, (nh,)))
        tril = jnp.tril(jnp.ones((cs, cs), jnp.float32))

        def chunk_body(carry, inp):
            C, n, m = carry                  # stabilized state @ scale e^m
            qc, kc, vc, igc, lfc = inp
            cumf = jnp.cumsum(lfc, axis=1)                       # L_i (b,cs,nh)
            # per-position stabilizer: m_i = max(L_i + m_prev,
            #                                    max_{j<=i}(L_i - L_j + ig_j))
            a = cumf + m[:, None, :]                             # carry path
            intra = jax.lax.cummax(igc - cumf, axis=1) + cumf    # intra path
            m_i = jnp.maximum(a, intra)                          # (b,cs,nh)
            dmat = (cumf[:, :, None, :] - cumf[:, None, :, :]
                    + igc[:, None, :, :] - m_i[:, :, None, :])
            # mask the upper triangle BEFORE exp: dmat is only <= 0 for
            # j <= i; exp of the (positive) upper triangle overflows and
            # inf * 0 = NaN under a post-exp tril multiply
            dmat = jnp.where(tril[None, :, :, None] > 0, dmat, -jnp.inf)
            w = jnp.exp(dmat)
            qk = jnp.einsum("bihk,bjhk->bijh", qc, kc)
            aw = w * qk
            num = jnp.einsum("bijh,bjhv->bihv", aw, vc)
            den = aw.sum(2)                                      # (b,cs,nh)
            # inter-chunk contribution from carried (stabilized) state
            dec_i = jnp.exp(a - m_i)                             # <= 1
            num = num + jnp.einsum("bhvk,bihk,bih->bihv", C, qc, dec_i)
            den = den + jnp.einsum("bhk,bihk,bih->bih", n, qc, dec_i)
            h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
            # state update at the end-of-chunk stabilizer m_c
            m_c = m_i[:, -1, :]
            tot = cumf[:, -1:, :]
            wj = jnp.exp(tot - cumf + igc - m_c[:, None, :])
            fsc = jnp.exp(tot[:, 0, :] + m - m_c)
            C = fsc[:, :, None, None] * C \
                + jnp.einsum("bjh,bjhv,bjhk->bhvk", wj, vc, kc)
            n = fsc[:, :, None] * n \
                + jnp.einsum("bjh,bjhk->bhk", wj, kc)
            return (C, n, m_c), h

        C0 = state["C"] if state is not None else jnp.zeros((b, nh, dh, dh),
                                                            jnp.float32)
        n0 = state["n"] if state is not None else jnp.zeros((b, nh, dh),
                                                            jnp.float32)
        m0 = state["m"] if state is not None else jnp.zeros((b, nh),
                                                            jnp.float32)
        # remat per chunk (see mamba2: avoids stacking (b, cs, cs, nh)
        # gated-attention residuals across chunks in the backward pass)
        (C, n, m_fin), hs = jax.lax.scan(jax.checkpoint(chunk_body),
                                         (C0, n0, m0), inputs)
        h = jnp.moveaxis(hs, 0, 1).reshape(b, nc * cs, nh, dh)[:, :s]
        h = h.reshape(b, s, d)
        new_state = {"C": C, "n": n, "m": m_fin}
    og = jax.nn.sigmoid((x @ p["ogate"].astype(x.dtype)).astype(jnp.float32))
    out = (h * og).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], d, 4 * d, pdtype(cfg)),   # z, i, f, o pre-acts
        "wh": dense_init(ks[1], d, 4 * d, pdtype(cfg)),   # recurrent
        "wo": dense_init(ks[2], d, d, pdtype(cfg)),
    }


def slstm_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  state: Optional[Dict[str, jax.Array]] = None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Sequential scan over time.  state: {"h","c","n","m"} each (B, D)."""
    b, s, d = x.shape
    pre = (x @ p["wx"].astype(x.dtype)).astype(jnp.float32)   # (B,S,4D)
    wh = p["wh"].astype(jnp.float32)

    if state is None:
        state = {k: jnp.zeros((b, d), jnp.float32) for k in ("h", "c", "n")}
        state["m"] = jnp.full((b, d), -1e30, jnp.float32)

    def step(carry, pre_t):
        h, c, n, m = carry
        g = pre_t + h @ wh
        z, i, f, o = jnp.split(g, 4, axis=-1)
        zt = jnp.tanh(z)
        ot = jax.nn.sigmoid(o)
        logf = jax.nn.log_sigmoid(f)
        m_t = jnp.maximum(logf + m, i)
        isc = jnp.exp(i - m_t)
        fsc = jnp.exp(logf + m - m_t)
        c_t = fsc * c + isc * zt
        n_t = fsc * n + isc
        h_t = ot * c_t / jnp.maximum(jnp.abs(n_t), 1.0)
        return (h_t, c_t, n_t, m_t), h_t

    carry = (state["h"], state["c"], state["n"], state["m"])
    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(pre, 1, 0))
    h_seq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)            # (B,S,D)
    out = h_seq @ p["wo"].astype(x.dtype)
    new_state = dict(zip(("h", "c", "n", "m"), carry))
    return out, new_state


def init_xlstm_state(cfg: ModelConfig, batch: int, kind: str
                     ) -> Dict[str, jax.Array]:
    nh, dh = _dims(cfg)
    d = cfg.d_model
    if kind == "mlstm":
        return {"C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
                "n": jnp.zeros((batch, nh, dh), jnp.float32),
                "m": jnp.zeros((batch, nh), jnp.float32)}
    st = {k: jnp.zeros((batch, d), jnp.float32) for k in ("h", "c", "n")}
    st["m"] = jnp.full((batch, d), -1e30, jnp.float32)
    return st
