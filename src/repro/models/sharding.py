"""Logical-axis sharding rules with divisibility fallback.

The production meshes are fixed by the launch spec (16x16 ``(data, model)``
single-pod, 2x16x16 ``(pod, data, model)`` multi-pod), but the assigned
architectures have head/kv/vocab counts that are not all divisible by 16
(qwen has 40 heads, paligemma 8/1, whisper's vocab is odd).  JAX rejects
uneven shardings outright, so every logical tensor dimension carries a
*fallback chain*: the first mesh-axis assignment whose size divides the
dimension wins; otherwise the dimension is replicated.

The scheme is Megatron-style TP+SP crossed with ZeRO-3/FSDP:

* ``model`` axis: attention heads / kv heads (or head_dim when head counts
  don't divide), FFN hidden, experts (EP), vocab, and the *sequence* axis of
  layer-boundary activations (sequence parallelism — saved activations under
  scan+remat are S-sharded, gathered inside the layer).
* ``data`` axis (plus ``pod`` outer axis when present): batch, and the
  d_model axis of every weight (FSDP; gathered per-layer inside scan).

``ShardingRules.spec(logical_axes, shape)`` resolves one tensor;
``mesh_axes(...)`` gives the raw tuple form for tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "LOGICAL_RULES", "logical_spec", "shard_like",
           "axis_size"]

AxisChoice = Union[str, Tuple[str, ...]]

#: logical dimension name -> ordered fallback chain of mesh-axis assignments.
#: Entries may be a single mesh axis or a tuple (sharded over the product).
LOGICAL_RULES: Dict[str, Sequence[AxisChoice]] = {
    # activations
    "batch": (("pod", "data"), "data"),
    "seq_act": ("model",),          # layer-boundary activations (SP)
    "seq": (),                       # in-layer sequence: replicated
    "embed_act": (),                 # activation d_model: replicated
    # weights
    "embed": ("data",),              # weight d_model axis (FSDP)
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),          # fallback used by KV caches
    "qkv_out": ("model",),           # flattened h*dh weight output axis
    "ffn": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "layers": (),                    # scan axis: never sharded
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv_k": (),
    # cache
    "cache_batch": (("pod", "data"), "data"),
    "cache_seq": (),
    "cache_kv": ("model", ),
    "cache_dim": ("model",),
}


def _flat(choice: AxisChoice) -> Tuple[str, ...]:
    return (choice,) if isinstance(choice, str) else tuple(choice)


@dataclass(frozen=True)
class ShardingRules:
    """Resolves logical axis names to mesh axes for a concrete mesh."""

    mesh: Mesh
    rules: Dict[str, Sequence[AxisChoice]] = field(
        default_factory=lambda: dict(LOGICAL_RULES))

    def _axis_prod(self, axes: Tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def resolve(self, logical: Optional[str], dim: int) -> Optional[AxisChoice]:
        """First candidate whose mesh size divides ``dim`` (and exists)."""
        if logical is None:
            return None
        for choice in self.rules.get(logical, ()):
            axes = _flat(choice)
            if not all(a in self.mesh.shape for a in axes):
                continue
            if dim % self._axis_prod(axes) == 0:
                return choice if isinstance(choice, str) else tuple(choice)
        return None

    def mesh_axes(self, logical_axes: Sequence[Optional[str]],
                  shape: Sequence[int]) -> Tuple[Optional[AxisChoice], ...]:
        if len(logical_axes) != len(shape):
            raise ValueError(f"rank mismatch: {logical_axes} vs {shape}")
        out = []
        used: set = set()
        for name, dim in zip(logical_axes, shape):
            choice = self.resolve(name, dim)
            # one mesh axis may shard only one dim of a tensor
            if choice is not None:
                axes = set(_flat(choice))
                if axes & used:
                    choice = None
                else:
                    used |= axes
            out.append(choice)
        return tuple(out)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        return P(*self.mesh_axes(logical_axes, shape))

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    # -- conveniences ------------------------------------------------------
    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Mesh axes that carry data parallelism (for psum of grads etc.)."""
        choice = None
        for c in self.rules["batch"]:
            axes = _flat(c)
            if all(a in self.mesh.shape for a in axes):
                choice = axes
                break
        return choice or ()

    @property
    def model_axis(self) -> Optional[str]:
        return "model" if "model" in self.mesh.shape else None

    def data_size(self) -> int:
        return self._axis_prod(self.batch_axes)

    def model_size(self) -> int:
        return self.mesh.shape.get("model", 1)


def logical_spec(rules: ShardingRules, tree: Any, axes_tree: Any) -> Any:
    """Maps a pytree of logical-axis tuples to PartitionSpecs."""
    flat_t, treedef = jax.tree_util.tree_flatten(
        tree, is_leaf=lambda x: hasattr(x, "shape"))
    flat_a = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten(
        [rules.spec(a, x.shape) for x, a in zip(flat_t, flat_a)])


def shard_like(rules: ShardingRules, x: jax.Array,
               logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(
            x, rules.sharding(logical_axes, x.shape))
    except (ValueError, RuntimeError):
        return x


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)
