"""Mamba2 (SSD) block for the zamba2 hybrid architecture.

Multi-head state-space duality form (Dao & Gu 2024), implemented with a
chunked scan: within a chunk the quadratic (attention-like) form runs on the
MXU; states propagate across chunks with a ``lax.scan``.  This keeps the
compiled HLO small (one chunk body) and gives O(S) sequence cost, which is
what qualifies zamba2 for the ``long_500k`` shape.

Decode uses the O(1) recurrent update on the carried state
``h: (B, heads, d_head, d_state)``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import dense_init, pdtype

Params = Dict[str, Any]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = max(1, d_inner // 64)          # mamba2 convention: head dim 64
    d_head = d_inner // n_heads
    return d_inner, n_heads, d_head, cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, nh, dh, ds = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [x, z, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * ds + nh, pdtype(cfg)),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, d_inner + 2 * ds),
                                    pdtype(cfg)) * 0.2,
        "A_log": jnp.zeros((nh,), pdtype(cfg)),
        "D": jnp.ones((nh,), pdtype(cfg)),
        "dt_bias": jnp.zeros((nh,), pdtype(cfg)),
        "out_proj": dense_init(ks[5], d_inner, d, pdtype(cfg)),
        "norm_scale": jnp.ones((d_inner,), pdtype(cfg)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv along S.  x: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    if state is not None:   # decode: state (B, K-1, C)
        xin = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    new_state = xin[:, -(k - 1):]
    # k shifted views (depthwise FIR filter)
    out = sum(xin[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    return jax.nn.silu(out), new_state


def mamba2_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                   chunk: int = 256,
                   state: Optional[Dict[str, jax.Array]] = None
                   ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: (B, S, D).  ``state`` given -> single-step decode (S small)."""
    b, s, _ = x.shape
    d_inner, nh, dh, ds = _dims(cfg)

    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xc, B_, C_, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + ds, 2 * d_inner + 2 * ds],
        axis=-1)
    conv_in = jnp.concatenate([xc, B_, C_], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv_w"], None if state is None else state["conv"])
    xc = conv_out[..., :d_inner]
    B_ = conv_out[..., d_inner:d_inner + ds]
    C_ = conv_out[..., d_inner + ds:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (nh,)
    xh = xc.reshape(b, s, nh, dh)

    if state is not None and s == 1:
        # O(1) recurrence: h' = exp(A dt) h + dt * x  outer B
        h = state["ssm"]                                          # (B,nh,dh,ds)
        da = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
        upd = (dt[:, 0, :, None, None]
               * xh[:, 0, :, :, None].astype(jnp.float32)
               * B_[:, 0, None, None, :].astype(jnp.float32))
        h = da * h + upd
        y = jnp.einsum("bhds,bs->bhd", h, C_[:, 0].astype(jnp.float32))
        y = y + p["D"].astype(jnp.float32)[None, :, None] \
            * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, d_inner).astype(x.dtype)
        new_state = {"ssm": h, "conv": conv_state}
    else:
        # chunked SSD scan
        pad = (-s) % chunk
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
            C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        nc = (s + pad) // chunk
        xh_c = xh.reshape(b, nc, chunk, nh, dh)
        B_c = B_.reshape(b, nc, chunk, ds)
        C_c = C_.reshape(b, nc, chunk, ds)
        dt_c = dt.reshape(b, nc, chunk, nh)

        def chunk_body(h, inp):
            xck, bck, cck, dtk = inp                 # (b,chunk,...)
            # per-step decay a_t = exp(A dt_t); cumulative within chunk
            la = dtk * A[None, None, :]              # log a_t  (b,c,nh)
            cum = jnp.cumsum(la, axis=1)             # L_t = sum_{<=t}
            # intra-chunk (quadratic) term: mask decay between positions
            # S_ij = exp(L_i - L_j) dt_j (C_i . B_j) x_j   for j <= i
            ci = cum[:, :, None, :]                  # (b,i,1,nh)
            cj = cum[:, None, :, :]                  # (b,1,j,nh)
            tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
            decay = jnp.exp(jnp.clip(ci - cj, -60.0, 0.0)) \
                * tril[None, :, :, None]             # j > i -> 0
            cb = jnp.einsum("bis,bjs->bij", cck.astype(jnp.float32),
                            bck.astype(jnp.float32))
            w = decay * cb[:, :, :, None] * dtk[:, None, :, :]   # (b,i,j,nh)
            y_intra = jnp.einsum("bijh,bjhd->bihd", w,
                                 xck.astype(jnp.float32))
            # inter-chunk: contribution of carried state
            dec_i = jnp.exp(cum)                     # (b,i,nh)
            y_inter = jnp.einsum("bis,bhds,bih->bihd",
                                 cck.astype(jnp.float32), h, dec_i)
            # state update: h' = exp(L_chunk) h + sum_j exp(L_c - L_j) dt_j x_j B_j
            tot = cum[:, -1:, :]                     # (b,1,nh)
            decay_j = jnp.exp(jnp.clip(tot - cum, -60.0, None))  # (b,j,nh)
            contrib = jnp.einsum("bjh,bjhd,bjs->bhds",
                                 decay_j * dtk, xck.astype(jnp.float32),
                                 bck.astype(jnp.float32))
            h_new = jnp.exp(tot[:, 0, :, None, None]) * h + contrib
            return h_new, (y_intra + y_inter)

        h0 = state["ssm"] if state is not None else \
            jnp.zeros((b, nh, dh, ds), jnp.float32)
        inputs = (jnp.moveaxis(xh_c, 1, 0), jnp.moveaxis(B_c, 1, 0),
                  jnp.moveaxis(C_c, 1, 0), jnp.moveaxis(dt_c, 1, 0))
        # remat the chunk: otherwise backward stacks the (b, chunk, chunk,
        # nh) intra-chunk decay/attention matrices across all chunks.
        h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, inputs)
        y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, nh, dh)[:, :s]
        y = y + p["D"].astype(jnp.float32)[None, None, :, None] \
            * xh.reshape(b, nc * chunk, nh, dh)[:, :s].astype(jnp.float32)
        y = y.reshape(b, s, d_inner).astype(x.dtype)
        new_state = {"ssm": h_fin, "conv": conv_state}  # prefill -> decode

    # gated RMSNorm + output projection
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = (yf * yf).mean(-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = yf.astype(x.dtype) @ p["out_proj"].astype(x.dtype)
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    d_inner, nh, dh, ds = _dims(cfg)
    return {"ssm": jnp.zeros((batch, nh, dh, ds), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * ds),
                              jnp.bfloat16)}
