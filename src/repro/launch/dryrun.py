import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first initialization, and the dry-run needs 512
placeholder host devices to build the production meshes.  (Only this
module does that: smoke tests and benchmarks see the real single device.)

For each cell this driver:

1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
2. resolves the sharding rules (logical axes -> mesh axes with
   divisibility fallbacks) for params / optimizer / batch / cache,
3. ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` — proving the
   distribution config is coherent (sharding propagation, collectives,
   layouts) without allocating anything,
4. records ``compiled.memory_analysis()`` (fits-per-device proof),
   ``cost_analysis()``, and the scan-corrected roofline terms
   (`repro.launch.roofline`) into a JSON artifact for EXPERIMENTS.md.

Usage::

    python -m repro.launch.dryrun --arch xlstm-125m --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --mesh both \
        --out artifacts/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..models import steps as steps_mod
from ..models.sharding import ShardingRules
from ..optim import AdamWConfig, constant
from . import roofline as rl
from .mesh import make_production_mesh
from .specs import (SHAPES, cell_shardings, default_microbatches,
                    input_specs, skip_reason)

__all__ = ["run_cell", "main"]


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _build_step(cfg, kind: str, rules: ShardingRules, microbatches: int = 1,
                opt_cfg: AdamWConfig = AdamWConfig(),
                acc_dtype: str = "float32"):
    if kind == "train":
        return steps_mod.make_train_step(cfg, constant(3e-4), opt_cfg,
                                         rules=rules,
                                         microbatches=microbatches,
                                         acc_dtype=acc_dtype)
    if kind == "prefill":
        return steps_mod.make_prefill_step(cfg, rules=rules)
    return steps_mod.make_decode_step(cfg, rules=rules)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             save_hlo: Optional[str] = None,
             donate: bool = True, microbatches: Optional[int] = None,
             opt_cfg: AdamWConfig = AdamWConfig(),
             acc_dtype: str = "float32",
             cfg=None) -> Dict[str, Any]:
    cfg = cfg if cfg is not None else get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "family": cfg.family}
    reason = skip_reason(cfg, shape_name)
    if reason:
        rec["skipped"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rules = ShardingRules(mesh)
    kind, specs = input_specs(cfg, shape_name, opt_cfg)
    shardings = cell_shardings(cfg, rules, shape_name, opt_cfg)
    rec["kind"] = kind
    if microbatches is None:
        microbatches = default_microbatches(cfg, shape_name, rules)
    rec["microbatches"] = microbatches

    step = _build_step(cfg, kind, rules, microbatches, opt_cfg, acc_dtype)
    sp = SHAPES[shape_name]

    if kind == "train":
        args = (specs["state"], specs["batch"])
        in_sh = (_named(mesh, shardings["state"]),
                 _named(mesh, shardings["batch"]))
        metrics_struct = jax.eval_shape(step, *args)[1]
        out_sh = (in_sh[0], jax.tree.map(
            lambda _: NamedSharding(mesh, P()), metrics_struct))
        donate_argnums = (0,) if donate else ()
    elif kind == "prefill":
        args = (specs["params"], specs["batch"], specs["cache"])
        logits_spec = rules.spec(("batch", None, "vocab"),
                                 (sp.global_batch, 1, cfg.vocab))
        in_sh = (_named(mesh, shardings["params"]),
                 _named(mesh, shardings["batch"]),
                 _named(mesh, shardings["cache"]))
        out_sh = (NamedSharding(mesh, logits_spec), in_sh[2])
        donate_argnums = (2,) if donate else ()
    else:
        args = (specs["params"], specs["tokens"], specs["cache"])
        logits_spec = rules.spec(("batch", None, "vocab"),
                                 (sp.global_batch, 1, cfg.vocab))
        in_sh = (_named(mesh, shardings["params"]),
                 NamedSharding(mesh, shardings["tokens"]),
                 _named(mesh, shardings["cache"]))
        out_sh = (NamedSharding(mesh, logits_spec), in_sh[2])
        donate_argnums = (2,) if donate else ()

    t0 = time.time()
    lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate_argnums).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name} x {mesh_name}] memory_analysis:", mem)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # older jax: one dict per partition
        ca = ca[0] if ca else {}
    print(f"[{arch} x {shape_name} x {mesh_name}] cost_analysis flops:",
          ca.get("flops"), "bytes:", ca.get("bytes accessed"))

    rep = rl.analyze_compiled(compiled, n_devices=n_dev)
    mf = rl.model_flops(cfg, sp)
    per_dev_mf = mf / n_dev
    rec.update(
        t_lower_s=round(t_lower, 2), t_compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            alias_bytes=mem.alias_size_in_bytes,
            peak_bytes=mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes,
        ),
        cost_analysis=dict(flops=ca.get("flops"),
                           bytes_accessed=ca.get("bytes accessed")),
        roofline=rep.as_dict(),
        model_flops_global=mf,
        model_flops_per_device=per_dev_mf,
        useful_flops_ratio=(per_dev_mf / rep.flops) if rep.flops else None,
        roofline_fraction=(per_dev_mf / rl.PEAK_FLOPS) / rep.t_bound
        if rep.t_bound else None,
    )
    if save_hlo:
        os.makedirs(os.path.dirname(save_hlo) or ".", exist_ok=True)
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
        rec["hlo_path"] = save_hlo
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"architecture id or 'all' ({ARCH_IDS})")
    ap.add_argument("--shape", default="all",
                    help=f"shape name or 'all' ({list(SHAPES)})")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="override the gradient-accumulation heuristic")
    ap.add_argument("--factored-opt", action="store_true",
                    help="Adafactor-style factored 2nd moment + bf16 mu")
    ap.add_argument("--acc-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="gradient-accumulation buffer dtype")
    args = ap.parse_args(argv)
    opt_cfg = AdamWConfig(factored_nu=args.factored_opt,
                          mu_dtype="bfloat16" if args.factored_opt
                          else "float32")

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                hlo = os.path.join(args.out, tag + ".hlo.txt") \
                    if args.save_hlo else None
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, save_hlo=hlo,
                                   microbatches=args.microbatches,
                                   opt_cfg=opt_cfg,
                                   acc_dtype=args.acc_dtype)
                except Exception as e:        # noqa: BLE001
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                    if args.fail_fast:
                        raise
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1, default=str)
                status = ("SKIP" if rec.get("skipped")
                          else "FAIL" if rec.get("error") else "OK")
                extra = ""
                if status == "OK":
                    peak = rec["memory"]["peak_bytes"] / 2**30
                    extra = (f" peak={peak:.2f}GiB "
                             f"bottleneck={rec['roofline']['bottleneck']} "
                             f"compile={rec['t_compile_s']}s")
                print(f"[{status}] {tag}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
