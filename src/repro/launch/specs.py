"""Input/state ShapeDtypeStruct specs + shardings for every dry-run cell.

``input_specs(cfg, shape_name)`` returns (step_kind, kwargs) where kwargs
are ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no device
allocation):

* ``train_4k``    -> ``train_step(state, batch)``
* ``prefill_32k`` -> ``prefill_step(params, batch, cache)``
* ``decode_32k`` / ``long_500k`` -> ``decode_step(params, tokens, cache)``
  (one new token against a KV cache of seq_len)

``long_500k`` requires sub-quadratic sequence mixing and is only emitted
for hybrid/ssm families (``cfg.supports_long_context``); full-attention
architectures skip it (recorded, per the assignment).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as model_mod, steps as steps_mod
from ..models.config import ModelConfig
from ..models.sharding import ShardingRules, logical_spec
from ..optim.adamw import AdamWConfig, OptState

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "state_sharding",
           "batch_sharding", "cache_sharding", "params_sharding",
           "skip_reason"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def default_microbatches(cfg: ModelConfig, shape_name: str,
                         rules: ShardingRules,
                         act_budget_bytes: float = 2 * 2**30) -> int:
    """Gradient-accumulation factor for train cells.

    Sizes the remat-saved activation stack (n_layers x B/data x S/model x
    d_model x 2B under sequence-parallel sharding) against a per-device
    budget; k must divide the per-data-shard batch.
    """
    sp = SHAPES[shape_name]
    if sp.kind != "train":
        return 1
    data = rules.data_size()
    model = rules.model_size()
    b_loc = max(1, sp.global_batch // data)
    s_loc = max(1, sp.seq_len // model)
    layers = cfg.n_layers + cfg.n_encoder_layers
    saved = layers * b_loc * s_loc * cfg.d_model * 2
    k = 1
    while saved / k > act_budget_bytes and k < b_loc and (b_loc % (k * 2) == 0):
        k *= 2
    return k


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return (f"{cfg.name} is pure full attention (O(S^2) prefill / O(S) "
                f"per-token KV); long_500k requires sub-quadratic mixing "
                f"(run only for hybrid/ssm) — see DESIGN.md")
    return None


# ---------------------------------------------------------------------------
# batch / cache / params specs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_struct(cfg: ModelConfig, b: int, s: int,
                 with_mask: bool = False) -> Dict[str, Any]:
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if with_mask:
        batch["mask"] = _sds((b, s), jnp.float32)
    if cfg.family == "vlm":
        batch["vision"] = _sds((b, cfg.n_vision_tokens, cfg.d_model),
                               jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                               jnp.bfloat16)
    return batch


def batch_axes_tree(cfg: ModelConfig, with_mask: bool = False) -> Dict[str, Any]:
    axes = {"tokens": ("batch", None)}
    if with_mask:
        axes["mask"] = ("batch", None)
    if cfg.family == "vlm":
        axes["vision"] = ("batch", None, None)
    if cfg.family == "audio":
        axes["frames"] = ("batch", None, None)
    return axes


def params_struct(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        functools.partial(model_mod.init_params, cfg=cfg),
        jax.random.PRNGKey(0))


def cache_struct(cfg: ModelConfig, b: int, max_len: int) -> Any:
    return jax.eval_shape(
        lambda: model_mod.init_decode_cache(cfg, b, max_len))


def train_state_struct(cfg: ModelConfig,
                       opt_cfg: AdamWConfig = AdamWConfig()
                       ) -> steps_mod.TrainState:
    return jax.eval_shape(
        lambda k: steps_mod.init_train_state(k, cfg, opt_cfg=opt_cfg),
        jax.random.PRNGKey(0))


# -- sharding trees ---------------------------------------------------------


def params_sharding(cfg: ModelConfig, rules: ShardingRules) -> Any:
    return logical_spec(rules, params_struct(cfg), model_mod.param_axes(cfg))


def cache_sharding(cfg: ModelConfig, rules: ShardingRules, b: int,
                   max_len: int) -> Any:
    return logical_spec(rules, cache_struct(cfg, b, max_len),
                        model_mod.cache_axes(cfg))


def batch_sharding(cfg: ModelConfig, rules: ShardingRules, b: int, s: int,
                   with_mask: bool = False) -> Any:
    return logical_spec(rules, batch_struct(cfg, b, s, with_mask),
                        batch_axes_tree(cfg, with_mask))


def state_sharding(cfg: ModelConfig, rules: ShardingRules,
                   opt_cfg: AdamWConfig = AdamWConfig()) -> Any:
    """TrainState sharding: opt-state leaves mirror their parameters.

    Factored second moments (Adafactor mode) shard their row/col stats
    with the corresponding surviving parameter axes."""
    p_spec = params_sharding(cfg, rules)
    p_struct = params_struct(cfg)
    axes = model_mod.param_axes(cfg)

    def nu_spec(p, a):
        a = tuple(a)
        if opt_cfg.factored_nu and len(p.shape) >= 2:
            return {"vr": rules.spec(a[:-1], p.shape[:-1]),
                    "vc": rules.spec(a[:-2] + (a[-1],),
                                     p.shape[:-2] + p.shape[-1:])}
        return rules.spec(a, p.shape)

    nu = jax.tree.map(nu_spec, p_struct, axes,
                      is_leaf=lambda x: isinstance(x, tuple))
    return steps_mod.TrainState(
        params=p_spec,
        opt=OptState(mu=p_spec, nu=nu, master=p_spec,
                     count=jax.sharding.PartitionSpec()),
        step=jax.sharding.PartitionSpec(),
        comp=(),
    )


# ---------------------------------------------------------------------------
# the per-cell entry point
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape_name: str,
                opt_cfg: AdamWConfig = AdamWConfig()
                ) -> Tuple[str, Dict[str, Any]]:
    """(kind, kwargs-of-ShapeDtypeStructs) for one (arch x shape) cell."""
    sp = SHAPES[shape_name]
    b, s = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        return "train", {"state": train_state_struct(cfg, opt_cfg),
                         "batch": batch_struct(cfg, b, s, with_mask=True)}
    if sp.kind == "prefill":
        return "prefill", {"params": params_struct(cfg),
                           "batch": batch_struct(cfg, b, s),
                           "cache": cache_struct(cfg, b, s)}
    # decode: one new token against a cache of seq_len
    return "decode", {"params": params_struct(cfg),
                      "tokens": _sds((b, 1), jnp.int32),
                      "cache": cache_struct(cfg, b, s)}


def cell_shardings(cfg: ModelConfig, rules: ShardingRules,
                   shape_name: str,
                   opt_cfg: AdamWConfig = AdamWConfig()) -> Dict[str, Any]:
    sp = SHAPES[shape_name]
    b, s = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        return {"state": state_sharding(cfg, rules, opt_cfg),
                "batch": batch_sharding(cfg, rules, b, s, with_mask=True)}
    if sp.kind == "prefill":
        return {"params": params_sharding(cfg, rules),
                "batch": batch_sharding(cfg, rules, b, s),
                "cache": cache_sharding(cfg, rules, b, s)}
    return {"params": params_sharding(cfg, rules),
            "tokens": jax.sharding.PartitionSpec(
                rules.mesh_axes(("batch",), (b,))[0], None),
            "cache": cache_sharding(cfg, rules, b, s)}
