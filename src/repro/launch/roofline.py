"""Roofline-term extraction from compiled (post-SPMD) HLO.

``jax`` facts this is built on (verified empirically in this container):

* ``compiled.cost_analysis()`` reports **per-device** FLOPs/bytes on the
  partitioned module but counts every ``while`` body (= ``lax.scan`` layer
  stack) exactly ONCE — useless for deep models unless corrected.
* ``compiled.as_text()`` prints the partitioned module with one named
  computation per region; ``while`` ops name their condition/body regions
  and scan trip counts appear as ``constant(N)`` in the condition.

So the analyzer parses the HLO text:

1. split into named computations,
2. find ``while`` ops, resolve each body's trip count from the largest
   integer constant in its condition computation (jax emits
   ``compare(iter, constant(N)), direction=LT``),
3. accumulate per computation, weighting by the product of enclosing trip
   counts:
   * ``dot`` FLOPs (2 * numel(out) * prod(contracting dims)),
   * HBM traffic: operands + results of every *top-level* op in the
     computation (fusion boundaries are materialization points),
   * collective bytes per device with ring costs: all-reduce
     ``2(n-1)/n * B``, all-gather / reduce-scatter ``(n-1)/n * B``,
     all-to-all ``(n-1)/n * B``, collective-permute ``B``.

Hardware constants: TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM, ~50 GB/s/link
ICI (per chip).

The three roofline terms are *seconds per step on one chip*:

    compute    = FLOPs / PEAK_FLOPS
    memory     = HBM bytes / HBM_BW
    collective = collective bytes / ICI_BW
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field, asdict
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["RooflineReport", "analyze_hlo", "analyze_compiled",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW", "model_flops"]

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip (TPU v5e)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (we charge 1 link; see DESIGN)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# header like ``%name (args...) -> type {`` — args may contain nested
# parens (tuple types), so match only the name and trust the trailing brace
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|calls|fusion)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_REPL_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_REPL_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_nbytes(dt: str, shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _all_shapes(line: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(line):
        dt, dims = m.groups()
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _result_shapes(line: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Shapes of the value a line defines (tuple types -> several)."""
    m = _DEF_RE.match(line)
    if not m:
        return []
    # the type literal(s) sit between '=' and the op name; tuple types are
    # parenthesized.  Grab shapes up to the first opcode token '('.
    rhs = line[line.index("=") + 1:]
    op_m = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
    head = rhs[: op_m.start()] if op_m else rhs
    return _all_shapes(head)


@dataclass
class RooflineReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0          # ring-model bytes per device
    collective_counts: Dict[str, int] = field(default_factory=dict)
    collective_bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    dot_count: int = 0
    while_trip_counts: List[int] = field(default_factory=list)
    hbm_top: List[Tuple[float, str]] = field(default_factory=list)
    # CPU-lowering artifact tracking (TPU projection — see EXPERIMENTS.md):
    # XLA-CPU upcasts bf16 dot operands to f32, so f32 collectives that
    # would be bf16 on TPU and pure bf16<->f32 convert traffic are counted
    # separately.
    f32_collective_bytes: float = 0.0
    convert_traffic_bytes: float = 0.0

    @property
    def t_collective_tpu(self) -> float:
        """Collective term if f32 reductions ran in bf16 (TPU lowering)."""
        return (self.collective_bytes - 0.5 * self.f32_collective_bytes) / ICI_BW

    @property
    def t_memory_tpu(self) -> float:
        """Memory term without bf16<->f32 convert round-trips."""
        return max(0.0, self.hbm_bytes - self.convert_traffic_bytes) / HBM_BW

    # -- derived -----------------------------------------------------------
    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 t_bound=self.t_bound,
                 t_collective_tpu=self.t_collective_tpu,
                 t_memory_tpu=self.t_memory_tpu)
        return d


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------


def _split_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if stripped.startswith("ENTRY"):
                    entry = cur
            continue
        if stripped == "}" and not line.startswith("    "):
            # computation bodies are printed with 2-space indent; a brace at
            # col 0 closes the computation
            cur = None
            continue
        if stripped and cur is not None:
            # strip metadata: jax op_name strings contain op-like text
            # ("transpose(jvp())") that breaks substring-based op checks
            comps[cur].append(stripped.split(", metadata=")[0])
    return comps, entry


def _group_size(line: str, n_devices: int) -> int:
    m = _REPL_GROUPS.search(line)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    m = _REPL_IOTA.search(line)
    if m:
        return int(m.group(2))           # [ngroups, group_size]<=[N]
    return max(1, n_devices)


def _operands(line: str) -> List[str]:
    """Operand value names of an op line (post-opt HLO omits inline types)."""
    m = _DEF_RE.match(line)
    rest = line[m.end():] if m else line
    op_m = re.search(r"\b[a-z][a-z0-9\-]*\(", rest)
    if not op_m:
        return []
    depth = 0
    args = ""
    for ch in rest[op_m.end() - 1:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    return _OPERAND_RE.findall(args)


def _dot_flops(line: str, symtab: Dict[str, Tuple[str, Tuple[int, ...]]]
               ) -> float:
    """FLOPs of one dot line: 2 * numel(result) * prod(contracting dims)."""
    res = _result_shapes(line)
    if not res:
        return 0.0
    out_shape = res[0][1]
    ops = _operands(line)
    lhs_shape: Tuple[int, ...] = ()
    if ops and ops[0] in symtab:
        lhs_shape = symtab[ops[0]][1]
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if m and lhs_shape:
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                k *= lhs_shape[int(d)]
    numel = 1
    for d in out_shape:
        numel *= d
    return 2.0 * numel * max(k, 1)


_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "bitcast-convert(", "after-all(", "partition-id(",
             "replica-id(")


_COLLECTIVE_KINDS = ("all-gather-start", "all-gather", "all-reduce-start",
                     "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute-start",
                     "collective-permute")


def analyze_hlo(hlo: str, n_devices: int = 1,
                compression_ratio: float = 1.0,
                dp_collective_kinds: Tuple[str, ...] = (),
                breakdown: bool = False) -> RooflineReport:
    comps, entry = _split_computations(hlo)
    rep = RooflineReport()
    _contrib: Dict[str, float] = {}

    def note(line: str, bytes_: float) -> None:
        if breakdown and bytes_ > 0:
            key = line.split("metadata")[0][:120]
            _contrib[key] = _contrib.get(key, 0.0) + bytes_
    if entry is None and comps:
        entry = next(iter(comps))

    # symbol table: value name -> (dtype, shape) of its (first) result,
    # plus total bytes across tuple results for operand accounting.
    symtab: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
    sym_bytes: Dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            shapes = _result_shapes(line)
            if shapes:
                symtab[m.group(1)] = shapes[0]
                sym_bytes[m.group(1)] = sum(
                    _shape_nbytes(dt, sh) for dt, sh in shapes)

    def _slice_traffic(line: str) -> Optional[float]:
        """HBM bytes for (dynamic-)slice / DUS ops: only the slice moves.

        dynamic-slice reads+writes the slice (result); dynamic-update-slice
        reads the update operand and writes it in place (the rest of the
        buffer is not touched — XLA updates in place)."""
        if re.search(r"(?<![\w-])dynamic-update-slice\(", line):
            ops_ = _operands(line)
            upd = sym_bytes.get(ops_[1], 0) if len(ops_) > 1 else 0
            return 2.0 * upd
        if re.search(r"(?<![\w-])dynamic-slice\(", line) or \
                re.search(r"(?<![\w-])slice\(", line):
            res = _result_shapes(line)
            return 2.0 * sum(_shape_nbytes(dt, sh) for dt, sh in res)
        return None

    # per fused computation: parameter index -> slice-traffic bytes, for
    # parameters consumed ONLY by (dynamic-)slice / DUS ops.  A fusion that
    # slices one row out of a stacked buffer per loop iteration must not be
    # charged the whole buffer each time.
    fusion_param_traffic: Dict[str, Dict[int, float]] = {}
    # fused computations whose ROOT is a dynamic-update-slice write only the
    # update region, not the whole output buffer (in-place update)
    root_dus_out_bytes: Dict[str, float] = {}
    for cname, lines in comps.items():
        has_dus = None
        for line in lines:
            if re.search(r"\bdynamic-update-slice\(", line):
                ops_ = _operands(line)
                if len(ops_) > 1:
                    has_dus = float(sym_bytes.get(ops_[1], 0))
        if has_dus is not None:
            # a fused computation whose body updates a slice writes only
            # the update region (output buffer is updated in place)
            root_dus_out_bytes[cname] = has_dus

    _ALIAS_OPS = ("bitcast(", "copy(", "reshape(", "transpose(", "convert(")
    for cname, lines in comps.items():
        pnames: Dict[str, int] = {}
        for line in lines:
            pm = re.search(r"%([\w\.\-]+)\s*=\s*[^=]*parameter\((\d+)\)", line)
            if pm:
                pnames[pm.group(1)] = int(pm.group(2))
        if not pnames:
            continue
        # propagate param identity through zero-traffic view ops so
        # slice(bitcast(param)) is still recognized as slicing the param
        alias: Dict[str, str] = {p: p for p in pnames}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            ops_ = _operands(line)
            if ops_ and ops_[0] in alias and \
                    any(a in line for a in _ALIAS_OPS):
                alias[m.group(1)] = alias[ops_[0]]
        traffic: Dict[int, float] = {}
        full: set = set()
        for line in lines:
            if re.search(r"parameter\(\d+\)", line):
                continue
            ops_ = [alias.get(o, o) for o in _operands(line)]
            st = _slice_traffic(line)
            if _DEF_RE.match(line) and ops_ and ops_[0] in pnames and \
                    any(a in line for a in _ALIAS_OPS):
                continue                      # alias op: no traffic, no mark
            if st is not None and ops_ and ops_[0] in pnames:
                idx = pnames[ops_[0]]
                traffic[idx] = traffic.get(idx, 0.0) + st
                others = ops_[1:] if "dynamic-update-slice" not in line \
                    else ops_[2:]
                full.update(o for o in others if o in pnames)
            else:
                full.update(o for o in ops_ if o in pnames)
        for o in full:
            traffic.pop(pnames[o], None)
        if traffic:
            fusion_param_traffic[cname] = traffic

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, ()):
            consts += [int(c) for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    visited_stack: List[str] = []

    def walk(name: str, mult: float) -> None:
        if name not in comps or name in visited_stack:
            return
        visited_stack.append(name)
        for line in comps[name]:
            if any(op in line for op in _SKIP_OPS):
                continue
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.groups()
                tc = trip_count(cond)
                rep.while_trip_counts.append(tc)
                walk(body, mult * tc)
                walk(cond, mult * tc)
                continue
            mb = _BRANCHES_RE.search(line)
            if mb:
                for br in mb.group(1).split(","):
                    walk(br.strip().lstrip("%"), mult)
                continue

            res_shapes = _result_shapes(line)
            out_bytes = sum(_shape_nbytes(dt, sh) for dt, sh in res_shapes)
            op_names = _operands(line)

            st = _slice_traffic(line)
            if st is not None:
                rep.hbm_bytes += mult * st
                note(line, mult * st)
                continue

            # fusion internals stay on-chip: charge only operands/results,
            # with slice-only parameters charged at slice granularity.
            # calls/conditionals recurse; while handled above.
            called = _CALLED_RE.findall(line)
            is_fusion = "fusion(" in line
            if is_fusion:
                traffic = {}
                for c in called:
                    traffic = fusion_param_traffic.get(c, {})
                    if c in root_dus_out_bytes:
                        out_bytes = root_dus_out_bytes[c]
                    if traffic:
                        break
                opnd_bytes = sum(
                    traffic[i] if i in traffic else sym_bytes.get(o, 0)
                    for i, o in enumerate(op_names))
            else:
                opnd_bytes = sum(sym_bytes.get(o, 0) for o in op_names)
                if called:
                    for c in called:
                        if "fused" not in c:
                            walk(c, mult)

            kind = None
            for c in _COLLECTIVE_KINDS:
                if re.search(rf"\b{c}\(", line):
                    kind = c.replace("-start", "")
                    break
            if kind:
                n = _group_size(line, n_devices)
                payload = max(out_bytes, opnd_bytes)
                if kind == "all-reduce":
                    comm = 2.0 * (n - 1) / max(n, 1) * payload
                elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
                    comm = (n - 1) / max(n, 1) * payload
                else:                      # collective-permute
                    comm = payload
                if kind in dp_collective_kinds:
                    comm *= compression_ratio
                rep.collective_counts[kind] = rep.collective_counts.get(
                    kind, 0) + int(mult)
                rep.collective_bytes_by_kind[kind] = \
                    rep.collective_bytes_by_kind.get(kind, 0.0) + mult * comm
                rep.collective_bytes += mult * comm
                if res_shapes and res_shapes[0][0] == "f32":
                    rep.f32_collective_bytes += mult * comm
                rep.hbm_bytes += mult * (out_bytes + opnd_bytes)
                note(line, mult * (out_bytes + opnd_bytes))
                continue

            if re.search(r"\bdot\(", line):
                rep.dot_count += int(mult)
                rep.flops += mult * _dot_flops(line, symtab)
            rep.hbm_bytes += mult * (out_bytes + opnd_bytes)
            note(line, mult * (out_bytes + opnd_bytes))
            # pure bf16<->f32 converts (incl. kLoop wrapped_convert fusions)
            if ("convert(" in line or "wrapped_convert" in line) and \
                    res_shapes and res_shapes[0][0] in ("f32", "bf16"):
                ops0 = symtab.get(op_names[0]) if op_names else None
                if ops0 and {res_shapes[0][0], ops0[0]} == {"f32", "bf16"} \
                        and ops0[1] == res_shapes[0][1]:
                    rep.convert_traffic_bytes += mult * (out_bytes + opnd_bytes)

        visited_stack.pop()

    if entry:
        walk(entry, 1.0)
    if breakdown:
        rep.hbm_top = sorted(((v, k) for k, v in _contrib.items()),
                             reverse=True)[:24]
    return rep


def analyze_compiled(compiled, n_devices: int = 1, **kw) -> RooflineReport:
    return analyze_hlo(compiled.as_text(), n_devices=n_devices, **kw)


# ---------------------------------------------------------------------------
# analytic model FLOPs (the "useful compute" yardstick)
# ---------------------------------------------------------------------------


def bottleneck_advice(bottleneck: str, kind: str, family: str) -> str:
    """One sentence per (cell): what would move the dominant term down."""
    if bottleneck == "collective":
        if kind == "train":
            return ("fewer grad-accumulation microbatches and bf16 "
                    "reduce-scatter gradient reduction (§Perf A); "
                    "hierarchical pod-local reduction on the multi-pod mesh")
        if kind == "prefill":
            return ("pin the attention layout (KV-length sharding for "
                    "non-divisible head counts) so partial-score "
                    "all-reduces disappear (§Perf B)")
        return ("decode collectives are weight-gather dominated: "
                "weight-stationary TP (contract over the sharded axis with "
                "small output psums) instead of gathering weights")
    if bottleneck == "memory":
        if kind == "decode":
            return ("bandwidth-bound on weights+KV cache: fp8/int8 KV "
                    "cache, larger in-flight batch per chip, or "
                    "speculative decoding to amortize weight reads")
        if kind == "prefill":
            return ("fuse attention score blocks into VMEM (Pallas flash "
                    "kernel) so (qc, T) tiles never reach HBM; bf16 "
                    "probability blocks (§Perf B-2)")
        return ("activation/HBM traffic: larger fused attention tiles, "
                "fewer remat passes (selective policy), and removing the "
                "CPU-lowering f32 duplicate stacks (TPU-native bf16)")
    return ("compute-bound — the healthy case: raise per-chip batch or "
            "sequence to amortize the non-MXU overhead; check "
            "useful-FLOPs ratio for remat waste")


def model_flops(cfg, shape) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params.

    For decode, D = tokens processed in the step (= global_batch)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per sequence
