"""Serving driver: continuous-batched prefill + decode.

A deliberately small but real serving loop (the paper's kind is a compiler,
so training is the primary end-to-end driver; this demonstrates the serve
path used by the ``prefill_32k`` / ``decode_32k`` / ``long_500k`` shapes):

* fixed-size decode batch; finished sequences are replaced from a request
  queue (continuous batching at step granularity),
* one jitted prefill step + one jitted decode step per config,
* greedy or temperature sampling.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m --smoke \
        --requests 8 --max-new 32
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models import model as model_mod, steps as steps_mod
from ..models.config import ModelConfig

__all__ = ["Server", "main"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class Server:
    """Step-granularity continuous batching over a fixed decode batch."""

    def __init__(self, cfg: ModelConfig, params, *, batch: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.prefill_fn = jax.jit(steps_mod.make_prefill_step(cfg))
        self.decode_fn = jax.jit(steps_mod.make_decode_step(cfg),
                                 donate_argnums=2)
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * batch
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- internals ---------------------------------------------------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.temperature
                                      ).astype(jnp.int32)

    def _prefill_one(self, req: Request) -> Any:
        """Prefill a single request; returns (next_token, cache)."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        cache = model_mod.init_decode_cache(self.cfg, 1, self.max_len)
        batch = {"tokens": toks}
        if self.cfg.family == "vlm":
            batch["vision"] = jnp.zeros(
                (1, self.cfg.n_vision_tokens, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.bfloat16)
        logits, cache = self.prefill_fn(self.params, batch, cache)
        self.stats["prefills"] += 1
        return int(self._sample(logits[:, -1])[0]), cache

    def run(self, drain: bool = True) -> Dict[str, Any]:
        """Processes the queue until all requests complete."""
        caches: List[Any] = [None] * self.batch
        t0 = time.perf_counter()
        completed: List[Request] = []
        while True:
            # fill free slots from the queue (continuous batching)
            for i in range(self.batch):
                if self.slots[i] is None and self.queue:
                    req = self.queue.pop(0)
                    tok, cache = self._prefill_one(req)
                    req.out.append(tok)
                    self.slots[i] = req
                    caches[i] = cache
            live = [i for i in range(self.batch) if self.slots[i] is not None]
            if not live:
                break
            # decode one token for each live slot (batched per slot here;
            # the dry-run shapes exercise the fully-batched variant)
            for i in live:
                req = self.slots[i]
                tok = jnp.asarray([[req.out[-1]]], jnp.int32)
                logits, caches[i] = self.decode_fn(self.params, tok, caches[i])
                nxt = int(self._sample(logits[:, -1])[0])
                req.out.append(nxt)
                self.stats["decode_steps"] += 1
                self.stats["tokens"] += 1
                if len(req.out) >= req.max_new:
                    req.done = True
                    completed.append(req)
                    self.slots[i] = None
                    caches[i] = None
            if not drain and not self.queue:
                break
        dt = time.perf_counter() - t0
        return {"completed": len(completed), "wall_s": dt,
                "tokens_per_s": self.stats["tokens"] / max(dt, 1e-9),
                **self.stats}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = model_mod.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg, params, batch=args.batch,
                 max_len=args.prompt_len + args.max_new + 1,
                 temperature=args.temperature)
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        srv.submit(Request(rid=r,
                           prompt=rng.integers(1, cfg.vocab,
                                               args.prompt_len),
                           max_new=args.max_new))
    out = srv.run()
    print(json.dumps(out, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
