"""Launch layer: meshes, dry-run lowering, roofline analysis, drivers."""
