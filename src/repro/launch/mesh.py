"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls it.

Mesh topology (TPU v5e pods):

* single-pod: 16 x 16 = 256 chips, axes ``(data, model)`` — ``data``
  carries FSDP + batch DP, ``model`` carries TP/SP/EP.
* multi-pod: 2 x 16 x 16 = 512 chips, axes ``(pod, data, model)`` — the
  ``pod`` axis is an outer data-parallel axis crossing the DCN; gradient
  reduction over ``pod`` is hierarchical (reduce within pod over ICI, then
  across pods).
"""

from __future__ import annotations

import os
import re
from typing import Dict, Optional

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh", "make_data_mesh",
           "forced_host_devices_env"]


def forced_host_devices_env(n: int, env: Optional[Dict[str, str]] = None
                            ) -> Dict[str, str]:
    """Environment for a child process with ``n`` forced host devices.

    Device count is fixed at jax import, so multi-device CPU runs
    (sharding tests, the serve benchmark) happen in subprocesses; this
    replaces any existing ``--xla_force_host_platform_device_count`` in
    ``XLA_FLAGS`` rather than appending a duplicate.
    """
    env = dict(os.environ if env is None else env)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = " ".join(
        flags.split() + [f"--xla_force_host_platform_device_count={n}"])
    return env


def _mk(shape, axes) -> Mesh:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:                      # no axis_types kwarg yet
            pass
    return jax.make_mesh(shape, axes)          # older jax: no AxisType


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the host actually has."""
    n = jax.device_count()
    if data * model > n:
        data, model = n, 1
    return _mk((data, model), ("data", "model"))


def make_data_mesh(data: int = 0) -> Mesh:
    """1-D ``("data",)`` mesh over ``min(data, device_count)`` devices.

    The search-plan engine shards CAM gallery rows over this axis (the
    bank level of the paper's §III-B hierarchy); ``data=0`` takes every
    device the host has.  Requests beyond the host's device count clamp
    rather than fail, so a plan compiled for 8-way sharding degrades to
    whatever the machine provides.
    """
    n = jax.device_count()
    data = n if data <= 0 else min(data, n)
    return _mk((data,), ("data",))
