"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls it.

Mesh topology (TPU v5e pods):

* single-pod: 16 x 16 = 256 chips, axes ``(data, model)`` — ``data``
  carries FSDP + batch DP, ``model`` carries TP/SP/EP.
* multi-pod: 2 x 16 x 16 = 512 chips, axes ``(pod, data, model)`` — the
  ``pod`` axis is an outer data-parallel axis crossing the DCN; gradient
  reduction over ``pod`` is hierarchical (reduce within pod over ICI, then
  across pods).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_local_mesh"]


def _mk(shape, axes) -> Mesh:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:                      # no axis_types kwarg yet
            pass
    return jax.make_mesh(shape, axes)          # older jax: no AxisType


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the host actually has."""
    n = jax.device_count()
    if data * model > n:
        data, model = n, 1
    return _mk((data, model), ("data", "model"))
