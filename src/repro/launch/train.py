"""End-to-end training driver.

Wires every substrate together: config -> mesh/sharding rules -> data
pipeline -> jitted train step -> supervisor (checkpoint / recovery /
straggler monitor).  On this CPU container it trains reduced configs for
real (examples/train_lm.py); on a TPU fleet the same driver runs the full
configs — only ``--mesh`` changes.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, get_smoke_config
from ..data import ShardedLoader, TokenStream
from ..distributed import (ErrorFeedbackInt8, ErrorFeedbackTopK,
                           NoCompression, RecoveryConfig, StragglerMonitor,
                           Supervisor)
from ..models import steps as steps_mod
from ..models.config import ModelConfig
from ..models.sharding import ShardingRules
from ..optim import AdamWConfig, warmup_cosine
from .mesh import make_local_mesh
from .specs import state_sharding

__all__ = ["TrainLoop", "main"]


COMPRESSORS = {"none": lambda: NoCompression(),
               "int8": lambda: ErrorFeedbackInt8(),
               "topk": lambda: ErrorFeedbackTopK(density=0.1)}


class TrainLoop:
    """Reusable training harness (used by the driver and the examples)."""

    def __init__(self, cfg: ModelConfig, *, batch: int, seq: int,
                 steps: int, lr: float = 3e-4, warmup: int = 50,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 compression: str = "none", seed: int = 0,
                 mesh=None, fail_at: Optional[int] = None):
        self.cfg = cfg
        self.n_steps = steps
        self.mesh = mesh if mesh is not None else make_local_mesh()
        self.rules = ShardingRules(self.mesh) if self.mesh.size > 1 else None
        self.compressor = COMPRESSORS[compression]()
        if isinstance(self.compressor, NoCompression):
            self.compressor = None

        self.stream = TokenStream(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=seed)
        self.loader = ShardedLoader(self.stream)
        key = jax.random.PRNGKey(seed)
        self.state = steps_mod.init_train_state(key, cfg, self.compressor)
        if self.rules is not None:
            spec = state_sharding(cfg, self.rules)
            spec = spec._replace(comp=jax.tree.map(
                lambda _: P(), self.state.comp))
            self.state = jax.device_put(self.state, jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), spec,
                is_leaf=lambda x: isinstance(x, P)))

        schedule = warmup_cosine(lr, warmup, steps)
        # no donate here: zero-initialized state leaves (mu/nu/error
        # feedback) can alias the same constant buffer, and donating an
        # aliased buffer twice is a runtime error on real arrays
        self.step_fn = jax.jit(steps_mod.make_train_step(
            cfg, schedule, AdamWConfig(), rules=self.rules,
            compressor=self.compressor))

        self.monitor = StragglerMonitor()
        self.fail_at = fail_at
        self.history: list = []
        ckpt_dir = ckpt_dir or os.path.join("artifacts", "ckpt", cfg.name)
        self.supervisor = Supervisor(RecoveryConfig(
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every))

    # ------------------------------------------------------------------
    def _one_step(self, state, step: int):
        from ..distributed.recovery import SimulatedFailure
        if self.fail_at is not None and step == self.fail_at:
            self.fail_at = None          # fail exactly once
            raise SimulatedFailure(f"injected chip failure at step {step}")
        # batches are addressed BY STEP (pure function of (seed, step)), so
        # restore-and-replay after a failure consumes exactly the same data
        batch = {k: jnp.asarray(self.loader.host_slice(v))
                 for k, v in self.stream.batch(step).items()}
        self.monitor.start()
        state, metrics = self.step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step_time_s"] = self.monitor.stop()
        return state, metrics

    def run(self) -> Dict[str, Any]:
        def on_metrics(step, m):
            self.history.append(m)
            if step % 10 == 0 or step == self.n_steps:
                print(f"step {step:5d} loss={m['loss']:.4f} "
                      f"acc={m['accuracy']:.3f} gnorm={m['grad_norm']:.2f} "
                      f"dt={m['step_time_s'] * 1e3:.0f}ms", flush=True)

        self.state, last = self.supervisor.run(
            self.state, self.n_steps, self._one_step,
            start_step=self.loader.step, on_metrics=on_metrics)
        stats = self.monitor.stats()
        return {"final": last, "restarts": self.supervisor.restarts,
                "slow_steps": self.monitor.slow_steps,
                "median_step_s": stats["median"], "history": self.history}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compression", default="none",
                    choices=list(COMPRESSORS))
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated failure at this step")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = TrainLoop(cfg, batch=args.batch, seq=args.seq, steps=args.steps,
                     lr=args.lr, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every,
                     compression=args.compression, fail_at=args.fail_at)
    if args.resume:
        state, step = loop.supervisor.restore(loop.state)
        loop.state = state
        loop.loader.step = step
        print(f"resumed from step {step}")
    out = loop.run()
    print(json.dumps({k: v for k, v in out.items() if k != "history"},
                     indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
